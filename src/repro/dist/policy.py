"""ShardingPolicy: the one object that carries "how is this run sharded".

Models never mention meshes or collectives directly (except inside their own
shard_map bodies); they take a ``ShardingPolicy`` and call
``policy.constrain(x, rule_name)`` at the layout boundaries DESIGN.md SS5
names. The policy is a mesh plus a dict of named PartitionSpec rules, so the
same model code runs:

  * single-device (``NO_SHARDING``): every constrain is a transparent no-op;
  * under any mesh: ``constrain`` applies ``with_sharding_constraint`` with a
    ``NamedSharding(mesh, rules[name])``; unknown rule names are no-ops, so a
    policy only needs to pin the boundaries it cares about.

Rule names are a closed vocabulary (see DESIGN.md SS5 for the full table):

  activations   act_btd (B,T,D) residual stream; act_attn_in (B,T,D) at the
                SP->TP boundary; act_bhsd (B,H,S,Dh) head-split attention;
                act_btf (B,T,F) FFN hidden; logits (B,T,V); kv_cache
                (L,B,Hkv,S,Dh)
  LM params     p_embed (V,D), p_head (D,V), p_norm, p_attn_in / p_attn_out,
                p_mlp_in / p_mlp_out, p_router, p_expert_in / p_expert_out
                -- stacked-layer leaves carry a leading (L,) axis, so the
                p_* specs for per-layer tensors start with None.

``lm_rules`` builds the standard TP/SP rule set (Megatron-style tensor
parallelism with sequence-parallel norm/residual regions) or, with
``pure_dp=True``, the ZeRO-1-style pure data-parallel set where every mesh
axis acts as batch and parameters are replicated.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Mesh axes that act as batch ("data-parallel") axes anywhere in the stack.
# launch/mesh.py builds ("data", "model") and ("pod", "data", "model").
DP_AXIS_NAMES = ("pod", "data")
TP_AXIS_NAME = "model"


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """A mesh + named PartitionSpec rules; the unit of sharding injection.

    mesh=None (or a rule name absent from ``rules``) makes every method a
    no-op / identity, so NO_SHARDING-path code is byte-identical to the
    sharded path minus the layout pins.
    """

    mesh: Mesh | None = None
    rules: Mapping[str, P] = dataclasses.field(default_factory=dict)

    # -- rule lookup -------------------------------------------------------

    def spec(self, name: str) -> P | None:
        """The PartitionSpec registered under ``name`` (None if absent)."""
        return self.rules.get(name)

    def sharding(self, name: str) -> NamedSharding | None:
        """NamedSharding for a rule, or None when unsharded/unknown."""
        spec = self.rules.get(name)
        if self.mesh is None or spec is None:
            return None
        return NamedSharding(self.mesh, spec)

    def constrain(self, x, name: str):
        """Pin ``x`` to the layout of rule ``name`` (identity if unknown)."""
        sh = self.sharding(name)
        if sh is None:
            return x
        return jax.lax.with_sharding_constraint(x, sh)

    # -- mesh geometry -----------------------------------------------------

    def dp_axes(self) -> tuple[str, ...]:
        """Mesh axes that shard the batch dimension, in mesh order."""
        if self.mesh is None:
            return ()
        return tuple(a for a in DP_AXIS_NAMES if a in self.mesh.shape)

    def axis_size(self, axis: str) -> int:
        if self.mesh is None or axis not in self.mesh.shape:
            return 1
        return int(self.mesh.shape[axis])

    @property
    def dp_size(self) -> int:
        size = 1
        for a in self.dp_axes():
            size *= self.axis_size(a)
        return size

    @property
    def model_axis_size(self) -> int:
        """Size of the tensor/model-parallel axis (1 without a mesh)."""
        return self.axis_size(TP_AXIS_NAME)

    @property
    def device_count(self) -> int:
        """Total device count of the mesh (1 without a mesh) — the shard
        count of anything row-sharded over every mesh axis (the RkMIPS
        engine's user/item rows, the staged build's row-parallel stages)."""
        if self.mesh is None:
            return 1
        return int(self.mesh.devices.size)


NO_SHARDING = ShardingPolicy(mesh=None, rules={})


def _axes_tuple(axes) -> tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


def lm_rules(dp_axes, tp_axis: str, *, pure_dp: bool = False) -> dict[str, P]:
    """The LM rule set launch/cells.py builds policies from.

    dp_axes: mesh axes sharding the batch (e.g. ("data",) or
    ("pod", "data")); tp_axis: the tensor-parallel axis ("model").

    pure_dp=True: ZeRO-1-style pure data parallelism -- every mesh axis
    (dp + tp) shards the batch, parameters are replicated (P() leaves;
    optimizer state is device-count-sharded separately by the cell builder).

    Default: TP/SP. Batch over dp. The residual stream (act_btd) is
    sequence-parallel (T over tp) between blocks; act_attn_in gathers the
    sequence axis once at the attention input (the SP->TP boundary), after
    which heads (act_bhsd), the FFN hidden (act_btf) and the vocab (logits)
    are tp-sharded. Parameter rules follow Megatron: column-parallel in
    (p_attn_in, p_mlp_in -> output-feature over tp), row-parallel out
    (p_attn_out, p_mlp_out -> input-feature over tp), vocab-sharded embedding
    and head, replicated norms and router, expert-sharded MoE weights
    (expert axis over tp == expert parallelism, models/moe.py). Per-layer
    p_* specs carry a leading None for the stacked (L,) layer axis.
    """
    dp = _axes_tuple(dp_axes)
    tp = tp_axis
    if pure_dp:
        batch = dp + (tp,)
        return {
            "act_btd": P(batch, None, None),
            "act_attn_in": P(batch, None, None),
            "act_bhsd": P(batch, None, None, None),
            "act_btf": P(batch, None, None),
            "logits": P(batch, None, None),
            "kv_cache": P(None, batch, None, None, None),
            "p_embed": P(), "p_head": P(), "p_norm": P(),
            "p_attn_in": P(), "p_attn_out": P(),
            "p_mlp_in": P(), "p_mlp_out": P(),
            "p_router": P(), "p_expert_in": P(), "p_expert_out": P(),
        }
    return {
        "act_btd": P(dp, tp, None),
        "act_attn_in": P(dp, None, None),
        "act_bhsd": P(dp, tp, None, None),
        "act_btf": P(dp, None, tp),
        "logits": P(dp, None, tp),
        "kv_cache": P(None, dp, None, None, None),
        "p_embed": P(tp, None),
        "p_head": P(None, tp),
        "p_norm": P(),
        "p_attn_in": P(None, None, tp),
        "p_attn_out": P(None, tp, None),
        "p_mlp_in": P(None, None, tp),
        "p_mlp_out": P(None, tp, None),
        "p_router": P(),
        "p_expert_in": P(None, tp, None, None),
        "p_expert_out": P(None, tp, None, None),
    }
