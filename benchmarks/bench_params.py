"""Appendix Figs. 3-5: parameter studies -- interval ratio b, sketch width
K (the paper's hash-table count), and cone leaf size N0.

The paper's findings to check: b=0.5 best trade-off (Fig. 3); accuracy
saturates around K=128 while time grows (Fig. 4); N0 is insensitive
(Fig. 5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro import RkMIPSEngine, get_config
from repro.core import metrics


def _measure(wl, k, **overrides):
    cfg = get_config("sah").replace(k_max=50, **overrides)
    eng = RkMIPSEngine(cfg).build(wl.items, wl.users, jax.random.PRNGKey(3))
    eng.query_batch(wl.queries, k)                       # warm (compile)
    res = eng.query_batch(wl.queries, k)
    dt = res.seconds / wl.queries.shape[0]
    f1 = float(jnp.mean(metrics.f1_score(res.predictions, wl.truth[k])))
    return dt, f1


def run(n=4096, m=8192, d=64, nq=8, k=10):
    wl = common.make_workload("nmf", n, m, d, nq, ks=(k,))
    rows = []
    for b in (0.1, 0.3, 0.5, 0.7, 0.9):
        dt, f1 = _measure(wl, k, b=b)
        rows.append(common.fmt_row(f"fig3/interval_b/{b}", dt * 1e6,
                                   f"f1={f1:.3f}"))
    for bits in (64, 128, 192, 256):
        dt, f1 = _measure(wl, k, n_bits=bits)
        rows.append(common.fmt_row(f"fig4/bits_K/{bits}", dt * 1e6,
                                   f"f1={f1:.3f}"))
    for leaf in (32, 64, 128, 256):
        dt, f1 = _measure(wl, k, leaf_size=leaf)
        rows.append(common.fmt_row(f"fig5/leaf_N0/{leaf}", dt * 1e6,
                                   f"f1={f1:.3f}"))
    return rows
