"""Appendix Figs. 3-5: parameter studies -- interval ratio b, sketch width
K (the paper's hash-table count), and cone leaf size N0.

The paper's findings to check: b=0.5 best trade-off (Fig. 3); accuracy
saturates around K=128 while time grows (Fig. 4); N0 is insensitive
(Fig. 5).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import metrics, sah


def _measure(wl, k, **build_kwargs):
    idx = sah.build(wl.items, wl.users, jax.random.PRNGKey(3),
                    k_max=50, **build_kwargs)
    jax.block_until_ready(idx.users)
    pred, _ = sah.rkmips_batch(idx, wl.queries, k, scan="sketch",
                               n_cand=64, tie_eps=common.TIE_EPS)
    jax.block_until_ready(pred)
    t0 = time.perf_counter()
    pred, _ = sah.rkmips_batch(idx, wl.queries, k, scan="sketch",
                               n_cand=64, tie_eps=common.TIE_EPS)
    jax.block_until_ready(pred)
    dt = (time.perf_counter() - t0) / wl.queries.shape[0]
    po = sah.predictions_to_original(idx, pred, wl.users.shape[0])
    f1 = float(jnp.mean(metrics.f1_score(po, wl.truth[k])))
    return dt, f1


def run(n=4096, m=8192, d=64, nq=8, k=10):
    wl = common.make_workload("nmf", n, m, d, nq, ks=(k,))
    rows = []
    for b in (0.1, 0.3, 0.5, 0.7, 0.9):
        dt, f1 = _measure(wl, k, b=b)
        rows.append(common.fmt_row(f"fig3/interval_b/{b}", dt * 1e6,
                                   f"f1={f1:.3f}"))
    for bits in (64, 128, 192, 256):
        dt, f1 = _measure(wl, k, n_bits=bits)
        rows.append(common.fmt_row(f"fig4/bits_K/{bits}", dt * 1e6,
                                   f"f1={f1:.3f}"))
    for leaf in (32, 64, 128, 256):
        dt, f1 = _measure(wl, k, leaf_size=leaf)
        rows.append(common.fmt_row(f"fig5/leaf_N0/{leaf}", dt * 1e6,
                                   f"f1={f1:.3f}"))
    return rows
