"""Architecture registry: every assigned arch is a selectable config
(``--arch <id>``) carrying its full config, a reduced smoke config, and its
shape cells for the dry-run."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode | serve | retrieval
    dims: dict         # shape parameters (family-specific)
    note: str = ""


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str        # lm | gnn | recsys
    make_config: Callable[[], Any]
    make_smoke_config: Callable[[], Any]
    shapes: tuple[ShapeSpec, ...]
    tp_heads: bool = True      # lm: attention-head TP divisible by 16
    pure_dp_train: bool = False  # lm: small models train pure-DP (single pod)
    train_grad_accum: int = 1  # lm: microbatching for activation memory
    source: str = ""
    notes: str = ""

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id} has no shape {name!r}")


_REGISTRY: dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.arch_id] = spec
    return spec


def get(arch_id: str) -> ArchSpec:
    _ensure_loaded()
    return _REGISTRY[arch_id]


def all_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    # Import side effects register every arch.
    from repro.configs import (  # noqa: F401
        dbrx_132b, olmoe_1b_7b, qwen3_0_6b, qwen2_1_5b, mistral_nemo_12b,
        gat_cora, xdeepfm, din, deepfm, two_tower_retrieval)


LM_SHAPES = (
    ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeSpec("prefill_32k", "prefill",
              {"seq_len": 32768, "global_batch": 32}),
    ShapeSpec("decode_32k", "decode",
              {"seq_len": 32768, "global_batch": 128}),
    ShapeSpec("long_500k", "decode",
              {"seq_len": 524288, "global_batch": 1},
              note="decode against a 500k KV cache is linear per step; run "
                   "with the cache sequence-sharded over the whole mesh "
                   "(DESIGN.md SS4). A 500k *prefill* would be quadratic and "
                   "is out of scope for these full-attention archs."),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "train", {"batch": 65536}),
    ShapeSpec("serve_p99", "serve", {"batch": 512}),
    ShapeSpec("serve_bulk", "serve", {"batch": 262144}),
    ShapeSpec("retrieval_cand", "retrieval",
              {"batch": 1, "n_candidates": 1_000_000}),
)
