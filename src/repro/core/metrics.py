"""Accuracy metrics for RkMIPS / kMIPS results."""

from __future__ import annotations

import jax.numpy as jnp


def f1_score(pred: jnp.ndarray, truth: jnp.ndarray,
             mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """F1 of boolean membership predictions against boolean truth.

    pred/truth: (..., m) boolean. mask: optional (..., m) validity mask.
    Returns F1 per leading batch element. Empty-truth & empty-pred counts as 1.
    """
    if mask is not None:
        pred = pred & mask
        truth = truth & mask
    tp = jnp.sum(pred & truth, axis=-1).astype(jnp.float32)
    np_ = jnp.sum(pred, axis=-1).astype(jnp.float32)
    nt = jnp.sum(truth, axis=-1).astype(jnp.float32)
    precision = jnp.where(np_ > 0, tp / jnp.maximum(np_, 1.0), 1.0)
    recall = jnp.where(nt > 0, tp / jnp.maximum(nt, 1.0), 1.0)
    denom = precision + recall
    f1 = jnp.where(denom > 0, 2 * precision * recall / jnp.maximum(denom, 1e-9), 0.0)
    both_empty = (np_ == 0) & (nt == 0)
    return jnp.where(both_empty, 1.0, f1)


def recall_at_k(pred_idx: jnp.ndarray, true_idx: jnp.ndarray) -> jnp.ndarray:
    """Set recall of predicted top-k ids vs true top-k ids, per row.

    pred_idx (..., k), true_idx (..., k) -> (...,) in [0, 1].
    """
    hits = (pred_idx[..., :, None] == true_idx[..., None, :]).any(axis=-1)
    return jnp.mean(hits.astype(jnp.float32), axis=-1)
