"""Optimizer, trainer, checkpoint, fault-tolerance, compression tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train import compression as comp
from repro.train import optimizer as opt_lib
from repro.train.trainer import TrainState, make_train_step, train_loop


def _quadratic_problem():
    target = jnp.asarray([1.0, -2.0, 3.0])

    def loss(params, batch):
        del batch
        return jnp.sum((params["w"] - target) ** 2)

    params = {"w": jnp.zeros(3)}
    return loss, params, target


@pytest.mark.parametrize("make_opt", [
    lambda: opt_lib.adamw(0.1),
    lambda: opt_lib.sgd(0.1, momentum=0.5),
    lambda: opt_lib.adafactor(0.5),
    lambda: opt_lib.chain(opt_lib.clip_by_global_norm(1.0),
                          opt_lib.adamw(0.1)),
    lambda: comp.error_feedback(opt_lib.adamw(0.1)),
])
def test_optimizers_converge(make_opt):
    loss, params, target = _quadratic_problem()
    opt = make_opt()
    step = make_train_step(loss, opt)
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    sj = jax.jit(step)
    for _ in range(300):
        state, metrics = sj(state, None)
    np.testing.assert_allclose(np.asarray(state.params["w"]),
                               np.asarray(target), atol=0.05)


def test_adamw_first_step_is_lr_sized():
    opt = opt_lib.adamw(0.1)
    params = {"w": jnp.asarray([1.0])}
    grads = {"w": jnp.asarray([0.5])}
    updates, _ = opt.update(grads, opt.init(params), params)
    # bias-corrected first step = -lr * g/|g| = -0.1
    np.testing.assert_allclose(np.asarray(updates["w"]), [-0.1], rtol=1e-4)


def test_adafactor_state_is_factored():
    opt = opt_lib.adafactor(0.1)
    params = {"w": jnp.zeros((32, 16)), "b": jnp.zeros((16,))}
    st = opt.init(params)
    assert st["v"]["w"]["r"].shape == (32,)
    assert st["v"]["w"]["c"].shape == (16,)
    assert st["v"]["b"]["full"].shape == (16,)


def test_grad_accum_matches_full_batch():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (4, 4))

    def loss(params, batch):
        return jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)

    params = {"w": w}
    opt = opt_lib.sgd(0.1, momentum=0.0)
    batch = {"x": jax.random.normal(key, (8, 4)),
             "y": jax.random.normal(jax.random.fold_in(key, 1), (8, 4))}
    s1 = make_train_step(loss, opt)
    s2 = make_train_step(loss, opt, grad_accum=4)
    st = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    out1, m1 = jax.jit(s1)(st, batch)
    out2, m2 = jax.jit(s2)(st, batch)
    np.testing.assert_allclose(np.asarray(out1.params["w"]),
                               np.asarray(out2.params["w"]), rtol=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2], jnp.int32)},
            "d": jnp.asarray(3.5, jnp.bfloat16)}
    ckpt.save(str(tmp_path), 7, tree, {"note": "x"})
    assert ckpt.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, meta = ckpt.restore(str(tmp_path), 7, like)
    assert meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_incomplete_not_visible(tmp_path):
    # a tmp dir (simulated crash mid-write) must be invisible to latest_step
    os.makedirs(tmp_path / ".tmp_step_00000009")
    assert ckpt.latest_step(str(tmp_path)) is None
    ckpt.save(str(tmp_path), 3, {"a": jnp.zeros(1)})
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(str(tmp_path), 1, {"a": jnp.zeros((3, 2))})


def test_checkpoint_prune(tmp_path):
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, {"a": jnp.zeros(1)})
    ckpt.prune(str(tmp_path), keep=2)
    steps = sorted(int(n[5:]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [4, 5]


def test_checkpoint_prune_protect(tmp_path):
    """protect= steps survive any keep budget — the artifact GC's
    guarantee that a retention policy can never delete the version it
    just saved, even one with a lower step number than existing steps."""
    def steps():
        return sorted(int(n[5:]) for n in os.listdir(tmp_path)
                      if n.startswith("step_"))

    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, {"a": jnp.zeros(1)})
    ckpt.prune(str(tmp_path), keep=2, protect=(1,))
    assert steps() == [1, 4, 5]                    # 1 survives the budget
    ckpt.prune(str(tmp_path), keep=1, protect=(1, 4))
    assert steps() == [1, 4, 5]
    ckpt.prune(str(tmp_path), keep=1)
    assert steps() == [5]
    # keep <= 0 deletes everything unprotected
    ckpt.save(str(tmp_path), 6, {"a": jnp.zeros(1)})
    ckpt.prune(str(tmp_path), keep=0, protect=(6,))
    assert steps() == [6]


def test_failure_recovery_resumes_identically(tmp_path):
    """Train 10 steps with a crash at step 6 + restart == uninterrupted."""
    loss, params_proto, _ = _quadratic_problem()
    opt = opt_lib.adamw(0.05)
    step = make_train_step(loss, opt)

    def fresh_params():
        # train_loop donates the state; each run needs its own buffers
        return jax.tree.map(lambda x: jnp.array(x, copy=True), params_proto)

    def data():
        while True:
            yield None

    # uninterrupted reference
    params = fresh_params()
    ref = train_loop(
        TrainState(params, opt.init(params), jnp.zeros((), jnp.int32)),
        step, data(), n_steps=10, log_every=100, log_fn=lambda s: None)

    # crash at step 6, recover from checkpoint (every 2 steps)
    cdir = str(tmp_path / "ck")
    params = fresh_params()
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    with pytest.raises(RuntimeError, match="simulated"):
        train_loop(state, step, data(), n_steps=10, ckpt_dir=cdir,
                   ckpt_every=2, fail_at_step=6, log_every=100,
                   log_fn=lambda s: None)
    last = ckpt.latest_step(cdir)
    assert last == 6
    params = fresh_params()
    like = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    state, _ = ckpt.restore(cdir, last, like)
    resumed = train_loop(state, step, data(), n_steps=10, log_every=100,
                         log_fn=lambda s: None)
    np.testing.assert_allclose(np.asarray(resumed.params["w"]),
                               np.asarray(ref.params["w"]), rtol=1e-6)


def test_int8_quantization_error_bound():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256,)) * 3.0
    q, s = comp.quantize_int8(x)
    err = jnp.max(jnp.abs(comp.dequantize_int8(q, s) - x))
    assert float(err) <= float(s) / 2 + 1e-6


def test_error_feedback_preserves_signal():
    """With EF, repeated identical gradients must not lose mass: the sum of
    compressed updates converges to the sum of true gradients."""
    inner = opt_lib.sgd(1.0, momentum=0.0)
    opt = comp.error_feedback(inner)
    params = {"w": jnp.zeros(4)}
    st = opt.init(params)
    g = {"w": jnp.asarray([1e-4, 1.0, -0.5, 2.0])}
    total = jnp.zeros(4)
    for _ in range(50):
        upd, st = opt.update(g, st, params)
        total = total + upd["w"]
    np.testing.assert_allclose(np.asarray(-total / 50),
                               np.asarray(g["w"]), rtol=0.02, atol=1e-4)


def test_watchdog_flags_stragglers():
    from repro.train.trainer import Watchdog
    wd = Watchdog(threshold=3.0)
    for _ in range(10):
        assert not wd.observe(0.1)
    assert wd.observe(1.0)
    assert wd.slow_steps == 1
