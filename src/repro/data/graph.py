"""Graph generation + host-side CSR neighbor sampler (GraphSAGE-style).

The `minibatch_lg` shape requires a real neighbor sampler: CSR adjacency on
host (numpy), fanout-limited multi-hop sampling producing fixed-size padded
subgraph batches for the device step.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray      # (N+1,)
    indices: np.ndarray     # (E,)
    features: np.ndarray    # (N, d)
    labels: np.ndarray      # (N,)

    @property
    def n_nodes(self) -> int:
        return self.indptr.shape[0] - 1


def random_power_law_graph(rng: np.random.Generator, n_nodes: int,
                           avg_degree: int, d_feat: int,
                           n_classes: int) -> CSRGraph:
    """Preferential-attachment-ish edge list -> CSR."""
    m = n_nodes * avg_degree
    # power-law targets: prob ~ rank^-0.8
    ranks = np.arange(1, n_nodes + 1, dtype=np.float64)
    p = ranks ** -0.8
    p /= p.sum()
    dst = rng.choice(n_nodes, size=m, p=p)
    src = rng.integers(0, n_nodes, size=m)
    order = np.argsort(dst, kind="stable")
    dst_s, src_s = dst[order], src[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, dst_s + 1, 1)
    indptr = np.cumsum(indptr)
    return CSRGraph(indptr=indptr, indices=src_s,
                    features=rng.standard_normal((n_nodes, d_feat),
                                                 dtype=np.float32),
                    labels=rng.integers(0, n_classes, n_nodes))


def sample_subgraph(rng: np.random.Generator, g: CSRGraph, seeds: np.ndarray,
                    fanout: tuple[int, ...], pad_nodes: int, pad_edges: int):
    """Fanout-limited k-hop sampled subgraph, padded to static shapes.

    Returns a dict matching models.gat.forward's graph layout with
    seed labels masked in. Node ids are remapped to [0, pad_nodes).
    """
    nodes = list(seeds)
    node_pos = {int(v): i for i, v in enumerate(seeds)}
    src_l, dst_l = [], []
    frontier = list(seeds)
    for f in fanout:
        nxt = []
        for v in frontier:
            lo, hi = g.indptr[v], g.indptr[v + 1]
            neigh = g.indices[lo:hi]
            if neigh.size > f:
                neigh = rng.choice(neigh, size=f, replace=False)
            for u in neigh:
                u = int(u)
                if u not in node_pos:
                    if len(nodes) >= pad_nodes:
                        continue
                    node_pos[u] = len(nodes)
                    nodes.append(u)
                    nxt.append(u)
                src_l.append(node_pos[u])
                dst_l.append(node_pos[v])
        frontier = nxt
    n, e = len(nodes), len(src_l)
    nodes_arr = np.asarray(nodes, np.int64)
    x = np.zeros((pad_nodes, g.features.shape[1]), np.float32)
    x[:n] = g.features[nodes_arr]
    src = np.zeros(pad_edges, np.int32)
    dst = np.zeros(pad_edges, np.int32)
    src[:e] = src_l
    dst[:e] = dst_l
    labels = np.zeros(pad_nodes, np.int32)
    labels[:n] = g.labels[nodes_arr]
    label_mask = np.zeros(pad_nodes, bool)
    label_mask[:len(seeds)] = True          # supervise seeds only
    emask = np.zeros(pad_edges, bool)
    emask[:e] = True
    return {"x": x, "src": src, "dst": dst, "edge_mask": emask,
            "labels": labels, "label_mask": label_mask}


def molecule_batch(rng: np.random.Generator, n_graphs: int, nodes_per: int,
                   edges_per: int, d_feat: int, n_classes: int,
                   pad_edges: int):
    """Block-diagonal batch of small graphs for graph-level classification."""
    n = n_graphs * nodes_per
    x = rng.standard_normal((n, d_feat), dtype=np.float32)
    src_l, dst_l = [], []
    for gi in range(n_graphs):
        off = gi * nodes_per
        s = rng.integers(0, nodes_per, edges_per) + off
        t = rng.integers(0, nodes_per, edges_per) + off
        src_l.append(s)
        dst_l.append(t)
    src = np.concatenate(src_l).astype(np.int32)
    dst = np.concatenate(dst_l).astype(np.int32)
    e = src.shape[0]
    src_p = np.zeros(pad_edges, np.int32)
    dst_p = np.zeros(pad_edges, np.int32)
    emask = np.zeros(pad_edges, bool)
    src_p[:e], dst_p[:e], emask[:e] = src, dst, True
    graph_id = np.repeat(np.arange(n_graphs, dtype=np.int32), nodes_per)
    return {"x": x, "src": src_p, "dst": dst_p, "edge_mask": emask,
            "graph_id": graph_id,
            "graph_labels": rng.integers(0, n_classes,
                                         n_graphs).astype(np.int32)}
