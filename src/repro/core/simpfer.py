"""Simpfer-style lower bounds (Amagata & Hara 2021), used by SAH Algorithm 4-5.

For each user u we store L_u[j] = (j+1)-th largest <u, p> over the top-norm
item prefix P' (the first n_top items in descending-norm order), j < k_max.
Because users are unit vectors here (Fact 2), a single sorted item order
serves every user.

Decision uses (strict-count convention of core/exact.py):
  * "no"  if tau < L_u[k-1]           (P' alone already has k items beating tau)
  * init_count(tau) = #{j : L_u[j] > tau} is EXACT whenever tau >= L_u[kmax-1]
    (any P' item outside the stored top-kmax has IP <= L_u[kmax-1] <= tau);
    when tau < L_u[kmax-1] the count is >= kmax >= k so the "no" rule already
    fired. Hence the scan over P \\ P' can start from init_count.
  * "yes" if tau >= ||p_k|| (the k-th largest item norm): at most k-1 items
    can have IP > tau since <u, p> <= ||p|| for unit u.

Block-level bounds L_B[j] = min_{u in B} L_u[j] (Algorithm 4 lines 11-14)
enable whole-block pruning against the node upper bound of Lemma 2.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def user_lower_bounds_impl(users_unit: jnp.ndarray, top_items: jnp.ndarray,
                           kmax: int, *, mask: jnp.ndarray | None = None
                           ) -> jnp.ndarray:
    """L (m, kmax) descending: top-kmax IPs of each user over P'.

    mask (n_top,) excludes retired P' members (their IPs become -inf, so
    they can neither fire the "no" rule nor inflate init_count) — the
    deletion-adjusted rebuild the artifact delta view uses
    (engine/artifact.py). When fewer than kmax members survive, the -inf
    tail keeps every bound vacuous and init_count exact over the
    survivors.

    Every row is independent (one dot per (user, item) pair plus a per-row
    top_k), which is what makes the stage trivially row-parallel over
    users: the staged build pipeline (engine/build.py) runs this
    undecorated body per user shard under ``shard_map``, bitwise equal to
    the full-matrix call. Call ``user_lower_bounds`` (the jitted alias)
    everywhere else.
    """
    ips = users_unit @ top_items.T                       # (m, n_top)
    if mask is not None:
        ips = jnp.where(mask[None, :], ips, -jnp.inf)
    vals, _ = jax.lax.top_k(ips, kmax)
    return vals


user_lower_bounds = functools.partial(
    jax.jit, static_argnames=("kmax",))(user_lower_bounds_impl)


def block_lower_bounds(user_lb_perm: jnp.ndarray, n_blocks: int
                       ) -> jnp.ndarray:
    """L_B (n_blocks, kmax) = min over each leaf's users (perm order)."""
    m_pad, kmax = user_lb_perm.shape
    return jnp.min(user_lb_perm.reshape(n_blocks, -1, kmax), axis=1)


def init_count(user_lb: jnp.ndarray, tau: jnp.ndarray) -> jnp.ndarray:
    """#{j : L_u[j] > tau} per user. user_lb (..., kmax), tau (...) -> int32."""
    return jnp.sum(user_lb > tau[..., None], axis=-1).astype(jnp.int32)
