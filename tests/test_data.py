"""Data pipeline tests: samplers, generators."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import graph as graph_data
from repro.data import synthetic


def test_power_law_graph_csr_valid():
    rng = np.random.default_rng(0)
    g = graph_data.random_power_law_graph(rng, 200, 8, 16, 5)
    assert g.indptr.shape == (201,)
    assert g.indptr[-1] == g.indices.shape[0]
    assert (np.diff(g.indptr) >= 0).all()
    assert (g.indices < 200).all() and (g.indices >= 0).all()


def test_neighbor_sampler_invariants():
    rng = np.random.default_rng(1)
    g = graph_data.random_power_law_graph(rng, 500, 10, 8, 3)
    seeds = np.arange(16)
    sub = graph_data.sample_subgraph(rng, g, seeds, (5, 3),
                                     pad_nodes=256, pad_edges=512)
    e = sub["edge_mask"].sum()
    assert e > 0
    # all real edges reference in-subgraph nodes
    assert (sub["src"][sub["edge_mask"]] < 256).all()
    assert (sub["dst"][sub["edge_mask"]] < 256).all()
    # fanout bound: each seed receives at most fanout[0] hop-1 edges
    hop1 = sub["dst"][sub["edge_mask"]]
    for s in range(16):
        assert (hop1 == s).sum() <= 5
    # supervision restricted to seeds
    assert sub["label_mask"][:16].all()
    assert not sub["label_mask"][16:].any()


def test_molecule_batch_block_diagonal():
    rng = np.random.default_rng(2)
    b = graph_data.molecule_batch(rng, 4, 6, 10, 8, 2, pad_edges=64)
    em = b["edge_mask"]
    gid_src = b["graph_id"][b["src"][em]]
    gid_dst = b["graph_id"][b["dst"][em]]
    np.testing.assert_array_equal(gid_src, gid_dst)  # no cross-graph edges


def test_recommendation_data_properties():
    items, users = synthetic.recommendation_data(
        jax.random.PRNGKey(0), 512, 1024, 32)
    assert items.shape == (512, 32) and users.shape == (1024, 32)
    # nmf-like: predominantly positive inner products
    ips = items[:64] @ users[:64].T
    assert float(jnp.mean(ips > 0)) > 0.95


def test_lm_token_batches():
    it = synthetic.lm_token_batches(jax.random.PRNGKey(0), 4, 16, 100,
                                    n_batches=3)
    batches = list(it)
    assert len(batches) == 3
    for b in batches:
        assert b["tokens"].shape == (4, 16)
        assert int(b["tokens"].max()) < 100
        np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                      np.asarray(b["labels"][:, :-1]))


def test_queries_from_items_top_band():
    items, _ = synthetic.recommendation_data(jax.random.PRNGKey(1), 256, 8,
                                             16)
    q = synthetic.queries_from_items(jax.random.PRNGKey(2), items, 8)
    norms = jnp.linalg.norm(items, axis=-1)
    thresh = jnp.sort(norms)[int(0.5 * 256)]
    assert float(jnp.min(jnp.linalg.norm(q, axis=-1))) >= float(thresh) * 0.5
