"""olmoe-1b-7b: 16L d_model=2048 16H (GQA kv=16) MoE 64 experts top-8,
d_ff_expert=1024, vocab=50304. [arXiv:2409.02060; hf]"""

import jax.numpy as jnp

from repro.configs import base
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16,
        n_kv_heads=16, d_head=128, d_ff=1024, vocab=50304,
        moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
        qk_norm=True, rope_theta=10000.0, dtype=jnp.bfloat16)


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="olmoe-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_head=32, d_ff=128, vocab=512, qk_norm=True,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=128),
        dtype=jnp.float32, max_seq=64, attn_chunk=32)


base.register(base.ArchSpec(
    arch_id="olmoe-1b-7b", family="lm", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=base.LM_SHAPES,
    tp_heads=True, source="arXiv:2409.02060",
    notes="64 experts top-8; EP over 'model' (4 experts/chip)"))
