"""Engine-level online serving (engine/serving.py) + padding hardening.

Pins the DESIGN.md SS8 contracts: (1) micro-batched serving answers are
identical to one-at-a-time engine queries — batching is a throughput knob,
never an accuracy knob; (2) the serving-state cache returns the identical
arrays on a hit and never rebuilds below capacity; (3) the dispatch
compiles exactly once per distinct batch size; (4) the sharding-layer
padding (``pad_index`` / ``pad_item_rows``) is bitwise-invisible after mask
stripping. The padding checks here are the hypothesis-free mirrors of
tests/test_core_properties.py, so they run on minimal installs too.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sa_alsh, sah
from repro.data import synthetic
from repro.dist.policy import NO_SHARDING
from repro.engine import (RetrievalServer, RkMIPSEngine, ServingCache,
                          build_serving_state, get_config)
from repro.engine import sharding as eng_sharding
from repro.kernels import ops as kops


@pytest.fixture(scope="module")
def corpus():
    key = jax.random.PRNGKey(11)
    ki, kq = jax.random.split(key)
    items, _ = synthetic.recommendation_data(ki, 509, 16, 24)   # prime n
    queries = synthetic.queries_from_items(kq, items, 7)
    return items, queries


@pytest.fixture(scope="module")
def server_cfg():
    return get_config("sah").replace(tile=128, n_bits=64, serve_batch_size=4)


def test_microbatch_matches_one_at_a_time_engine_kmips(corpus, server_cfg):
    """7 queries through B=4 micro-batches == 7 single engine.kmips calls
    (exact scan: both paths recover the true top-k)."""
    items, queries = corpus
    cfg = server_cfg.replace(scan="exact")
    eng = RkMIPSEngine(cfg).build(items, None, jax.random.PRNGKey(3))
    srv = eng.server()
    tickets = srv.submit(queries)
    assert tickets == list(range(7)) and srv.pending == 7
    res = srv.flush(5)
    assert len(res) == 7 and srv.pending == 0
    for i, r in enumerate(res):
        one = eng.kmips(queries[i], 5)
        np.testing.assert_array_equal(np.asarray(r.ids), np.asarray(one.ids))
        np.testing.assert_allclose(np.asarray(r.values),
                                   np.asarray(one.values), rtol=1e-6)
        assert r.k == 5


def test_microbatch_bitwise_equals_oneshot(corpus, server_cfg):
    """Micro-batched sketch dispatch is bitwise the one-shot batched scan:
    per-query rows are independent and the zero-query padding is dead."""
    items, queries = corpus
    srv = RetrievalServer(items, jax.random.PRNGKey(4), config=server_cfg)
    state = srv.cache.get(server_cfg)
    ucodes = kops.srp_hash(queries, state.proj_q)
    v0, i0 = eng_sharding.kmips_flat_arrays(
        state.items, state.item_ids, state.item_mask, state.codes, ucodes,
        queries, 5, NO_SHARDING, n_cand=server_cfg.n_cand)
    srv.submit(queries)
    res = srv.flush(5)
    np.testing.assert_array_equal(
        np.stack([np.asarray(r.ids) for r in res]), np.asarray(i0))
    np.testing.assert_array_equal(
        np.stack([np.asarray(r.values) for r in res]), np.asarray(v0))
    # single-query convenience path agrees too
    one = srv.kmips(queries[2], 5)
    np.testing.assert_array_equal(np.asarray(one.ids), np.asarray(i0[2]))


def test_cache_hit_returns_identical_arrays_without_rebuild(corpus,
                                                            server_cfg):
    items, _ = corpus
    cache = ServingCache(items, jax.random.PRNGKey(5), capacity=2)
    s1 = cache.get(server_cfg)
    assert cache.builds == 1
    s2 = cache.get(server_cfg)
    assert s2 is s1 and cache.builds == 1          # hit: same arrays, no build
    assert s2.items is s1.items and s2.codes is s1.codes
    # serve/query-only knobs don't change the built arrays: same entry
    assert cache.get(server_cfg.replace(serve_batch_size=2,
                                        serve_cache_capacity=9,
                                        n_cand=128)) is s1
    assert cache.builds == 1
    # LRU eviction past capacity forces a rebuild on the evicted key
    cache.get(server_cfg.replace(n_bits=32))
    cache.get(server_cfg.replace(n_bits=96))       # evicts server_cfg
    assert len(cache) == 2 and cache.builds == 3
    assert server_cfg not in cache
    s3 = cache.get(server_cfg)
    assert cache.builds == 4 and s3 is not s1
    np.testing.assert_array_equal(np.asarray(s3.codes), np.asarray(s1.codes))


def test_cache_lru_eviction_order(corpus, server_cfg):
    """LRU semantics under capacity pressure: a get() refreshes recency, so
    the evictee is the least-recently-USED entry, not the oldest-built;
    ``builds`` counts exactly the misses; query-only config changes share
    one entry (and refresh it)."""
    items, _ = corpus
    cache = ServingCache(items, jax.random.PRNGKey(21), capacity=2)
    cfg_a = server_cfg                                  # three distinct
    cfg_b = server_cfg.replace(n_bits=32)               # index recipes
    cfg_c = server_cfg.replace(n_bits=96)
    sa = cache.get(cfg_a)
    cache.get(cfg_b)
    assert len(cache) == 2 and cache.builds == 2
    # touch A via a query-only variant: same entry, recency refreshed
    assert cache.get(cfg_a.replace(n_cand=128, serve_batch_size=2)) is sa
    assert cache.builds == 2
    cache.get(cfg_c)                                    # evicts B, not A
    assert len(cache) == 2 and cache.builds == 3
    assert cfg_a in cache and cfg_c in cache and cfg_b not in cache
    assert cache.get(cfg_a) is sa and cache.builds == 3
    cache.get(cfg_b)                                    # miss: rebuild,
    assert cache.builds == 4                            # evicts C (LRU)
    assert cfg_c not in cache and cfg_a in cache
    # put() of a pre-built state counts no build and obeys capacity
    cache.put(cfg_c, build_serving_state(items, jax.random.PRNGKey(21),
                                         cfg_c))
    assert cache.builds == 4 and len(cache) == 2        # put counts no miss
    assert cfg_a not in cache                           # A was LRU by then
    with pytest.raises(ValueError, match=r"capacity must be >= 1"):
        ServingCache(items, jax.random.PRNGKey(21), capacity=0)


def test_server_ranks_with_engine_codes(corpus, server_cfg):
    """engine.server() must scan with the identical SRP codes as
    engine.kmips(), whether the engine's kMIPS index was built eagerly
    (users=None), lazily, or not at all yet — and a server seeded from an
    already-built index performs no build of its own."""
    items, queries = corpus
    eng = RkMIPSEngine(server_cfg).build(items, None, jax.random.PRNGKey(3))
    srv = eng.server()                             # index built eagerly
    assert srv.cache.builds == 0                   # seeded, not rebuilt
    state = srv.cache.get(server_cfg)
    assert srv.cache.builds == 0
    np.testing.assert_array_equal(np.asarray(state.codes),
                                  np.asarray(eng.kmips_index.codes))
    # sketch-scan answers agree with the engine's flat sharded path
    one = srv.kmips(queries[0], 5, n_cand=64)
    ref = eng.kmips(queries[0], 5, n_cand=509)     # full depth: exact
    assert set(np.asarray(one.ids)) <= set(range(items.shape[0]))
    np.testing.assert_array_equal(np.asarray(one.ids[:1]),
                                  np.asarray(ref.ids[:1]))
    # not-yet-materialized index: the server builds with the same key,
    # so the codes still match the engine's lazily-built index
    eng2 = RkMIPSEngine(server_cfg).build(items, items[:8],
                                          jax.random.PRNGKey(3))
    srv2 = eng2.server()
    assert srv2.cache.builds == 0 and server_cfg not in srv2.cache
    state2 = srv2.cache.get(server_cfg)            # built by the server
    assert srv2.cache.builds == 1
    np.testing.assert_array_equal(np.asarray(state2.codes)[:509],
                                  np.asarray(eng2.kmips_index.codes)[:509])


def test_flush_failures_keep_tickets(corpus, server_cfg):
    """An empty flush is free (no state build); a failed flush (bad k)
    consumes nothing — a retry answers every ticket."""
    items, queries = corpus
    srv = RetrievalServer(items, jax.random.PRNGKey(12), config=server_cfg)
    assert srv.flush(5) == [] and srv.cache.builds == 0
    srv.submit(queries[:2])
    # bound is the REAL corpus size (509), not the padded row count (512):
    # k=510 would otherwise return phantom (-1, -inf) tail entries
    with pytest.raises(ValueError, match=r"k=510 outside \[1, 509\]"):
        srv.flush(510)
    assert srv.pending == 2                        # queue survived the error
    res = srv.flush(5)
    assert len(res) == 2 and srv.pending == 0
    # a config swapped between flushes brings its own batch size
    srv.config = server_cfg.replace(serve_batch_size=2)
    assert srv.batch_size == 2
    srv.submit(queries[:3])
    assert len(srv.flush(5)) == 3


def test_seeded_and_rebuilt_states_agree():
    """A state seeded from the engine's index and one rebuilt by the cache
    (same key, same recipe) are interchangeable — identical shapes and
    codes even when the corpus is smaller than the config tile."""
    key = jax.random.PRNGKey(13)
    items = jax.random.normal(key, (50, 16))       # corpus < default tile
    cfg = get_config("sah").replace(n_bits=64, serve_cache_capacity=1)
    eng = RkMIPSEngine(cfg).build(items, None, key)
    srv = eng.server()
    seeded = srv.cache.get(cfg)
    assert srv.cache.builds == 0
    srv.cache.get(cfg.replace(n_bits=32))          # capacity 1: evicts seed
    rebuilt = srv.cache.get(cfg)                   # cache builds its own
    assert srv.cache.builds == 2
    assert rebuilt.items.shape == seeded.items.shape
    np.testing.assert_array_equal(np.asarray(rebuilt.codes),
                                  np.asarray(seeded.codes))
    np.testing.assert_array_equal(np.asarray(rebuilt.item_ids),
                                  np.asarray(seeded.item_ids))


def test_kmips_rejects_batch_without_enqueuing(corpus, server_cfg):
    items, queries = corpus
    srv = RetrievalServer(items, jax.random.PRNGKey(8), config=server_cfg)
    srv.submit(queries[0])
    with pytest.raises(ValueError, match=r"kmips serves one query"):
        srv.kmips(queries[:3], 5)
    assert srv.pending == 1                        # rejected rows not queued
    res = srv.flush(5)
    assert len(res) == 1


def test_submit_validates_queries_up_front(corpus, server_cfg):
    """Malformed queries are rejected AT SUBMIT with message-asserted
    ValueErrors — never enqueued, so they can't strand a later flush
    (which, by the retry contract, would leave the whole batch pending)."""
    items, queries = corpus
    srv = RetrievalServer(items, jax.random.PRNGKey(15), config=server_cfg)
    with pytest.raises(ValueError, match=r"submit: queries must have a "
                                         r"floating dtype, got int32"):
        srv.submit(np.ones((2, 24), np.int32))
    with pytest.raises(ValueError, match=r"submit: queries must be one row "
                                         r"\(d,\) or a block \(nq, d\), "
                                         r"got shape \(2, 3, 24\)"):
        srv.submit(np.ones((2, 3, 24), np.float32))
    with pytest.raises(ValueError, match=r"submit: query dimensionality 23 "
                                         r"!= corpus dimensionality 24"):
        srv.submit(np.ones((23,), np.float32))
    assert srv.pending == 0                        # nothing leaked in
    srv.submit(queries[0])                         # good rows still pass
    assert srv.pending == 1 and len(srv.flush(5)) == 1


def test_reverse_submit_validates_queries_up_front(reverse_engine):
    eng, queries = reverse_engine
    srv = eng.reverse_server()
    with pytest.raises(ValueError, match=r"floating dtype"):
        srv.submit(np.ones((2, 16), np.int64))
    with pytest.raises(ValueError, match=r"query dimensionality 8 != "
                                         r"corpus dimensionality 16"):
        srv.submit(np.ones((8,), np.float32))
    assert srv.pending == 0
    srv.submit(queries[0])
    assert srv.pending == 1 and len(srv.flush(3)) == 1


def test_one_compile_per_batch_size(corpus, server_cfg):
    items, queries = corpus
    srv = RetrievalServer(items, jax.random.PRNGKey(6), config=server_cfg)
    srv.submit(queries[:3])                        # partial batch (padded)
    srv.flush(5)
    assert srv.compile_count == 1
    srv.submit(queries)                            # 7 = full + partial batch
    srv.flush(5)
    srv.submit(queries[0])
    srv.flush(5)
    assert srv.compile_count == 1                  # every dispatch is (4, d)
    srv2 = RetrievalServer(items, jax.random.PRNGKey(6),
                           config=server_cfg.replace(serve_batch_size=2))
    srv2.submit(queries[:5])
    srv2.flush(5)
    assert srv2.compile_count == 1                 # its own (2, d) executable


def test_serving_state_invariants(corpus, server_cfg):
    """Padded rows are dead (-1 ids, mask off); real ids cover the corpus."""
    items, _ = corpus
    state = build_serving_state(items, jax.random.PRNGKey(7), server_cfg)
    ids = np.asarray(state.item_ids)
    mask = np.asarray(state.item_mask)
    assert state.n_items == items.shape[0]
    np.testing.assert_array_equal(np.sort(ids[mask]),
                                  np.arange(items.shape[0]))
    assert (ids[~mask] == -1).all()
    assert not np.asarray(state.items)[~mask].any()


# ---------------------------------------------------------------------------
# Reverse (RkMIPS) serving: a ticket queue over the batched plan/execute
# dispatch (DESIGN.md SS9) — batching is a throughput knob, never an
# accuracy knob, and serving adds no executables of its own.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def reverse_engine():
    key = jax.random.PRNGKey(19)
    ki, ku, kq = jax.random.split(key, 3)
    items, users = synthetic.recommendation_data(ki, 384, 512, 16)
    queries = synthetic.queries_from_items(kq, items, 7)
    cfg = get_config("sah").replace(tile=64, n_bits=32, k_max=8, n_top=8,
                                    serve_batch_size=4)
    eng = RkMIPSEngine(cfg).build(items, users, ku)
    return eng, queries


def test_reverse_microbatch_bitwise_equals_oneshot(reverse_engine):
    """7 tickets through B=4 micro-batches == the matching rows of one
    7-query query_batch — work-queue lanes are independent and the
    repeat-padding rows are discarded."""
    eng, queries = reverse_engine
    ref = eng.query_batch(queries, 3)
    srv = eng.reverse_server()
    tickets = srv.submit(queries)
    assert tickets == list(range(7)) and srv.pending == 7
    res = srv.flush(3)
    assert len(res) == 7 and srv.pending == 0
    for i, r in enumerate(res):
        np.testing.assert_array_equal(np.asarray(r.predictions),
                                      np.asarray(ref.predictions[i]))
        assert int(r.stats.n_scan) == int(ref.stats.n_scan[i])
        assert r.k == 3
    # single-query convenience path agrees too
    one = srv.rkmips(queries[2], 3)
    np.testing.assert_array_equal(np.asarray(one.predictions),
                                  np.asarray(ref.predictions[2]))


def test_reverse_server_shares_engine_executables(reverse_engine):
    """Every reverse flush dispatches at the serve batch size: one compile
    per distinct (batch size, k), shared with the engine — the server owns
    no dispatch of its own."""
    key = jax.random.PRNGKey(29)
    ki, ku = jax.random.split(key)
    items, users = synthetic.recommendation_data(ki, 256, 256, 16)
    cfg = get_config("sah").replace(tile=64, n_bits=32, k_max=8, n_top=8,
                                    serve_batch_size=4)
    eng = RkMIPSEngine(cfg).build(items, users, ku)
    srv = eng.reverse_server()
    srv.submit(items[:3])                  # partial batch (padded to 4)
    srv.flush(3)
    assert srv.compile_count == 1
    srv.submit(items[:7])                  # full + partial batch
    srv.flush(3)
    srv.submit(items[0])
    srv.flush(3)
    assert srv.compile_count == 1          # every dispatch is (4, d)
    assert srv.batch_size == 4
    # a one-shot engine batch of the same size reuses the same executable
    eng.query_batch(items[:4], 3)
    assert eng.rkmips_compile_count == 1


def test_reverse_flush_failures_keep_tickets(reverse_engine):
    eng, queries = reverse_engine
    srv = eng.reverse_server()
    assert srv.flush(3) == []
    srv.submit(queries[:2])
    with pytest.raises(ValueError, match=r"outside \[1, k_max=8\]"):
        srv.flush(9)                       # k > k_max: nothing consumed
    assert srv.pending == 2
    assert len(srv.flush(3)) == 2 and srv.pending == 0
    with pytest.raises(ValueError, match=r"rkmips serves one query"):
        srv.rkmips(queries[:2], 3)
    assert srv.pending == 0


def test_reverse_server_requires_user_side_build():
    key = jax.random.PRNGKey(31)
    items = jax.random.normal(key, (64, 8))
    eng = RkMIPSEngine(get_config("sah").replace(tile=32, n_bits=32)
                       ).build(items, None, key)
    with pytest.raises(RuntimeError, match=r"not built for RkMIPS"):
        eng.reverse_server()


# ---------------------------------------------------------------------------
# Padding equivalence, hypothesis-free mirrors (fixed non-divisible sizes).
# The drawn-size versions live in tests/test_core_properties.py.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,n,shards", [(53, 97, 3), (101, 67, 5),
                                        (96, 128, 7)])
def test_pad_index_rkmips_equivalence(m, n, shards):
    key = jax.random.PRNGKey(m + n + shards)
    ki, ku, kq, kb = jax.random.split(key, 4)
    items = jax.random.normal(ki, (n, 8))
    users = jax.random.normal(ku, (m, 8))
    q = jax.random.normal(kq, (8,)) * 2.0
    idx = sah.build(items, users, kb, k_max=4, n_top=4, tile=32,
                    leaf_size=8, n_bits=32)
    pidx = eng_sharding.pad_index(idx, shards)
    assert pidx.n_blocks % shards == 0
    for scan in ("sketch", "exact"):
        p0, s0 = sah.rkmips(idx, q, 3, n_cand=16, scan=scan)
        p1, s1 = sah.rkmips(pidx, q, 3, n_cand=16, scan=scan)
        np.testing.assert_array_equal(
            np.asarray(sah.predictions_to_original(idx, p0, m)),
            np.asarray(sah.predictions_to_original(pidx, p1, m)))
        for f in ("blocks_alive", "users_alive", "n_no_lb", "n_yes_norm",
                  "n_scan"):
            assert int(getattr(s0, f)) == int(getattr(s1, f)), (scan, f)
    # dead padding: each original id exactly once among unmasked rows
    ids = np.asarray(pidx.user_ids)[np.asarray(pidx.user_mask)]
    np.testing.assert_array_equal(np.sort(ids), np.arange(m))


@pytest.mark.parametrize("n,shards,k", [(97, 3, 5), (53, 7, 2), (64, 5, 1)])
def test_pad_item_rows_flat_scan_equivalence(n, shards, k):
    key = jax.random.PRNGKey(n * shards + k)
    ki, kq, kb = jax.random.split(key, 3)
    items = jax.random.normal(ki, (n, 12))
    queries = jax.random.normal(kq, (3, 12))
    idx = sa_alsh.build_index(items, kb, n_bits=32, tile=32)
    uc = sa_alsh.user_codes(idx, queries)
    padded = eng_sharding.pad_item_rows(idx.items, idx.item_ids,
                                        idx.item_mask, idx.codes, shards, k)
    assert padded[0].shape[0] % shards == 0
    assert padded[0].shape[0] // shards >= k
    for scan in ("sketch", "exact"):
        v0, i0 = eng_sharding.kmips_flat_arrays(
            idx.items, idx.item_ids, idx.item_mask, idx.codes, uc, queries,
            k, NO_SHARDING, n_cand=256, scan=scan)
        v1, i1 = eng_sharding.kmips_flat_arrays(*padded, uc, queries, k,
                                                NO_SHARDING, n_cand=256,
                                                scan=scan)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))


def test_result_mapping_drops_phantom_ids():
    """A phantom id (out of [0, n_users)) on a padding row must be dropped
    by predictions_to_original, never clamped onto a real user."""
    key = jax.random.PRNGKey(9)
    ki, ku, kb = jax.random.split(key, 3)
    items = jax.random.normal(ki, (40, 8))
    users = jax.random.normal(ku, (17, 8))
    idx = sah.build(items, users, kb, k_max=4, n_top=4, tile=32,
                    leaf_size=8, n_bits=32)
    pidx = eng_sharding.pad_index(idx, 5)
    m_pad = pidx.n_users
    # corrupt every padded (masked-off) slot with phantom ids AND force the
    # mask on, simulating a broken alternate padding convention
    pad_rows = jnp.arange(idx.n_users, m_pad)
    bad = pidx._replace(
        user_ids=pidx.user_ids.at[pad_rows].set(-1),
        user_mask=pidx.user_mask.at[pad_rows].set(True))
    all_yes = jnp.ones((m_pad,), bool)
    out = sah.predictions_to_original(bad, all_yes, 17)
    ref = sah.predictions_to_original(idx, jnp.ones((idx.n_users,), bool), 17)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
