import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record roofline inputs.

The two lines above MUST run before any other import (jax locks the device
count at first init). Do not replicate this flag anywhere global -- smoke
tests and benchmarks see 1 device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b \
        --shape train_4k --mesh single --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch two-tower-retrieval \
        --shape retrieval_cand --sah        # paper-technique sketch variant

Each cell writes <out>/<arch>__<shape>__<mesh>.json with memory_analysis,
cost_analysis, and per-kind collective bytes. Failures (sharding mismatch,
OOM at compile) are bugs in the system -- the process exits nonzero.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402


def _compile_cell(cell, mesh):
    # donate the state for train cells (matches the production trainer's
    # donate_argnums -- without it memory_analysis double-counts the state)
    donate = (0,) if cell.shape_name.startswith("train") or \
        cell.shape_name in ("full_graph_sm", "minibatch_lg", "ogb_products",
                            "molecule") else ()
    with mesh:
        jitted = jax.jit(cell.step, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*cell.abstract_args)
        return lowered.compile()


def run_cell(arch_id: str, shape_name: str, mesh_kind: str, out_dir: str,
             sah_variant: bool = False) -> dict:
    from repro.configs import base as cfg_base
    from repro.launch import cells as cells_lib
    from repro.launch import roofline as rl
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    t0 = time.time()
    if sah_variant:
        from repro.launch.serve import build_sah_retrieval_cell
        cell = build_sah_retrieval_cell(mesh)
        shape_name = "retrieval_cand_sah"
        arch_spec = None
    else:
        cell = cells_lib.build_cell(arch_id, shape_name, mesh)
        arch_spec = cfg_base.get(arch_id)

    t_lower = time.time() - t0
    compiled = _compile_cell(cell, mesh)
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    roof = rl.from_compiled(compiled)
    if cell.cost_scale != 1.0:
        roof = rl.Roofline(
            flops=roof.flops * cell.cost_scale,
            bytes_accessed=roof.bytes_accessed * cell.cost_scale,
            coll_bytes={k: v * cell.cost_scale
                        for k, v in roof.coll_bytes.items()},
            peak_memory=roof.peak_memory)
    if arch_spec is not None and arch_spec.family == "lm":
        # XLA cost_analysis counts the layer-scan body once: extrapolate
        # flops/bytes/collectives affine-in-L from unrolled L=1/L=2 variants
        # (layers are identical, so the extrapolation is exact; the full scan
        # compile above still provides the memory + compiles-at-depth proof).
        shape = arch_spec.shape(shape_name)
        r1 = rl.from_compiled(_compile_cell(
            cells_lib.build_lm_cell(arch_spec, shape, mesh, cost_layers=1),
            mesh))
        r2 = rl.from_compiled(_compile_cell(
            cells_lib.build_lm_cell(arch_spec, shape, mesh, cost_layers=2),
            mesh))
        n_l = arch_spec.make_config().n_layers
        roof = rl.Roofline(
            flops=r1.flops + (n_l - 1) * (r2.flops - r1.flops),
            bytes_accessed=r1.bytes_accessed
            + (n_l - 1) * (r2.bytes_accessed - r1.bytes_accessed),
            coll_bytes={k: r1.coll_bytes[k]
                        + (n_l - 1) * (r2.coll_bytes[k] - r1.coll_bytes[k])
                        for k in r1.coll_bytes},
            peak_memory=roof.peak_memory)
    try:
        mflops = rl.model_flops(arch_id, shape_name.replace("_sah", ""))
    except Exception:
        mflops = None

    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": mesh_kind,
        "n_devices": int(n_dev),
        "mesh_shape": {k: int(v) for k, v in mesh.shape.items()},
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "temp_bytes": int(mem.temp_size_in_bytes),
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "generated_code_bytes": int(mem.generated_code_size_in_bytes),
        },
        "roofline": roof.to_dict(),
        "model_flops_global": mflops,
        "note": cell.note,
    }
    # peak per-device bytes that must fit HBM:
    rec["memory"]["per_device_total"] = (
        rec["memory"]["temp_bytes"] + rec["memory"]["argument_bytes"]
        + rec["memory"]["output_bytes"] - rec["memory"]["alias_bytes"])
    if mflops is not None and roof.flops > 0:
        rec["useful_flops_ratio"] = mflops / (roof.flops * n_dev)

    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch_id}__{shape_name}__{mesh_kind}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--sah", action="store_true",
                    help="SAH sketch variant of two-tower retrieval_cand")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    from repro.configs import base as cfg_base

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch_id in cfg_base.all_archs():
            for s in cfg_base.get(arch_id).shapes:
                cells.append((arch_id, s.name))
    else:
        cells.append((args.arch, args.shape))

    failures = []
    for arch_id, shape_name in cells:
        for mesh_kind in meshes:
            tag = f"{arch_id} x {shape_name} x {mesh_kind}" + \
                (" [sah]" if args.sah else "")
            try:
                rec = run_cell(arch_id, shape_name, mesh_kind, args.out,
                               sah_variant=args.sah)
                r = rec["roofline"]
                print(f"OK   {tag}: compile={rec['compile_s']:.1f}s "
                      f"mem/dev={rec['memory']['per_device_total']/2**30:.2f}GiB "
                      f"compute={r['compute_s']*1e3:.2f}ms "
                      f"memory={r['memory_s']*1e3:.2f}ms "
                      f"coll={r['collective_s']*1e3:.2f}ms "
                      f"dom={r['dominant']}", flush=True)
            except Exception as e:  # noqa: BLE001
                failures.append(tag)
                print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:\n  " + "\n  ".join(failures))
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
