"""Shared benchmark utilities: datasets, oracles, registry-driven methods.

The method grid is the engine registry (repro/engine/config.py) — the
paper's baseline matrix lives in exactly one place, and every benchmark row
is produced by an ``RkMIPSEngine`` preset rather than hand-rolled kwargs.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import PAPER_BASELINES, RkMIPSEngine, display_name, get_config
from repro.core import exact, metrics
from repro.data import synthetic

# Queries come from the item set (see core/exact.py); every config in the
# registry carries the same tolerance, and the workload oracle must match.
TIE_EPS = get_config("sah").tie_eps


@dataclasses.dataclass
class Workload:
    name: str
    items: jnp.ndarray
    users: jnp.ndarray
    users_unit: jnp.ndarray
    queries: jnp.ndarray
    truth: dict          # k -> (nq, m) bool


def make_workload(name: str, n: int, m: int, d: int = 64, nq: int = 16,
                  ks=(1, 5, 10, 20, 30, 40, 50), kind: str = "nmf",
                  seed: int = 0) -> Workload:
    key = jax.random.PRNGKey(seed)
    ki, kq = jax.random.split(key)
    items, users = synthetic.recommendation_data(ki, n, m, d, kind=kind)
    queries = synthetic.queries_from_items(kq, items, nq)
    uu = users / jnp.linalg.norm(users, axis=-1, keepdims=True)
    truth = {k: exact.rkmips_batch_chunked(items, uu, queries, k,
                                           tie_eps=TIE_EPS) for k in ks}
    jax.block_until_ready(truth[ks[-1]])
    return Workload(name, items, users, uu, queries, truth)


# Method matrix: the paper's Fig.1 + Fig.2 ablation grid, by registry name.
METHODS = tuple(display_name(m) for m in PAPER_BASELINES)


def build_method(wl: Workload, method: str, k_max: int = 50,
                 n_bits: int = 128, seed: int = 1) -> tuple[RkMIPSEngine,
                                                            float]:
    """Build the preset engine for ``method`` (registry or display name)."""
    cfg = get_config(method).replace(k_max=k_max, n_bits=n_bits)
    eng = RkMIPSEngine(cfg)
    eng.build(wl.items, wl.users, jax.random.PRNGKey(seed))
    return eng, eng.build_seconds


def run_method(wl: Workload, eng: RkMIPSEngine, k: int):
    """-> (query_time_s_per_query, f1, stats). Warm run then timed run.

    Timings are the full public-API call (QueryResult.seconds), which
    includes the original-user-space mapping the seed benchmarks excluded —
    the honest serving latency, but slightly above pre-engine rows.
    """
    eng.query_batch(wl.queries, k)                       # warm (compile)
    res = eng.query_batch(wl.queries, k)
    dt = res.seconds / wl.queries.shape[0]
    f1 = float(jnp.mean(metrics.f1_score(res.predictions, wl.truth[k])))
    return dt, f1, res.stats


def fmt_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
