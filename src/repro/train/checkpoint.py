"""Checkpointing: atomic, manifest-validated, elastic (mesh-independent).

Layout of a checkpoint directory:
    <dir>/step_000100/
        manifest.json     step, timestamp, leaf index {path -> file, shape,
                          dtype}, user metadata (config hash, mesh shape, ...)
        arrays_00000.npz  leaf arrays (numpy, host-gathered)

Writes are atomic: everything lands in `<dir>/.tmp_step_N` and is renamed to
`step_N` only after the manifest is fsynced -- a crash mid-write can never
produce a directory that `latest_step()` would pick up.

Restores are *elastic*: arrays are loaded host-side and re-sharded to whatever
mesh/sharding the caller passes (or left as plain numpy on CPU), so a job may
resume on a different number of chips than it checkpointed from -- the
fault-tolerance / elastic-scaling primitive (DESIGN.md SS6).
"""

from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    return str(entry)


def save(ckpt_dir: str, step: int, tree, metadata: dict | None = None
         ) -> str:
    """Atomically save a pytree. Returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _flatten_with_paths(tree)
    index = {}
    arrays = {}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        name = f"a{i:05d}"
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V":  # ml_dtypes (bfloat16, fp8): store bits
            logical_dtype = str(jax.numpy.asarray(leaf).dtype)
            arr = np.frombuffer(
                np.ascontiguousarray(arr).tobytes(), np.uint8
            ).reshape(arr.shape + (arr.itemsize,))
        arrays[name] = arr
        index[key] = {"file": name, "shape": list(arr.shape),
                      "dtype": logical_dtype}
    np.savez(os.path.join(tmp, "arrays_00000.npz"), **arrays)

    manifest = {
        "step": step,
        "time": time.time(),
        "index": index,
        "metadata": metadata or {},
        "format": 1,
    }
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    """Largest step with a complete (manifest-bearing) checkpoint."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, "manifest.json")):
            try:
                steps.append(int(name[5:]))
            except ValueError:
                continue
    return max(steps) if steps else None


def read_manifest(ckpt_dir: str, step: int) -> dict:
    """The manifest of one saved step: leaf index (shapes/dtypes), user
    metadata, timestamps. Lets a consumer (e.g. the index-artifact loader,
    engine/artifact.py) build its own `like` tree for restore() without
    knowing the shapes a priori."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")
    with open(path) as f:
        return json.load(f)


def restore(ckpt_dir: str, step: int, like,
            shardings=None) -> tuple[Any, dict]:
    """Restore a pytree saved by save().

    `like` is a pytree with the same structure (values are ignored; shapes
    are validated). `shardings`: optional matching pytree of
    jax.sharding.Sharding to place the restored arrays on a (possibly
    different) mesh -- elastic restore. Returns (tree, metadata).
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = read_manifest(ckpt_dir, step)
    data = np.load(os.path.join(path, "arrays_00000.npz"))

    flat_like = _flatten_with_paths(like)
    treedef = jax.tree_util.tree_structure(like)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat_like))

    leaves = []
    for (key, leaf_like), shd in zip(flat_like, shard_flat):
        entry = manifest["index"].get(key)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[entry["file"]]
        want = tuple(np.shape(leaf_like))
        if tuple(arr.shape) != want:
            # bit-stored ml_dtypes leaf: (shape..., itemsize) uint8 view
            if arr.dtype == np.uint8 and tuple(arr.shape[:-1]) == want:
                import ml_dtypes
                arr = arr.reshape(-1).view(
                    np.dtype(entry["dtype"])).reshape(want)
            else:
                raise ValueError(
                    f"leaf {key!r}: checkpoint shape {arr.shape} != {want}")
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["metadata"]


def prune(ckpt_dir: str, keep: int = 3,
          protect: tuple | list | set = ()) -> None:
    """Delete all but the newest `keep` complete checkpoints.

    Steps in `protect` are never deleted, on top of the keep budget —
    the artifact GC (engine/artifact.py::IndexArtifact.save(keep=...))
    protects the step it just wrote, so a retention policy can never
    delete the live version, whatever its step number.
    """
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(n[5:]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_")
        and os.path.exists(os.path.join(ckpt_dir, n, "manifest.json")))
    protected = set(protect)
    doomed = steps if keep <= 0 else steps[:-keep]
    for s in doomed:
        if s in protected:
            continue
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"))
