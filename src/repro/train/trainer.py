"""Training loop: jit'd train_step factory, grad accumulation, checkpointing,
failure recovery, step-time watchdog (straggler detection).

The step function is model-agnostic: it takes any `loss_fn(params, batch)`
(configs bind the model + sharding policy). TrainState is a plain pytree so
checkpoint.py can save/restore it whole.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt_lib


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray


def init_state(params, optimizer: opt_lib.Optimizer) -> TrainState:
    return TrainState(params=params, opt_state=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(loss_fn: Callable, optimizer: opt_lib.Optimizer,
                    *, grad_accum: int = 1, grad_barrier: bool = False):
    """Returns step(state, batch) -> (state, metrics).

    With grad_accum > 1 the batch's leading axis is split into `grad_accum`
    microbatches scanned sequentially (activation memory / global batch
    trade-off).

    grad_barrier: materialize gradients (optimization_barrier) between the
    backward pass and the optimizer. Under data parallelism this pins the
    gradient all-reduce *before* the optimizer's f32 upcast, halving its
    wire bytes for bf16 params (EXPERIMENTS SSPerf cell 2, iteration 5).
    """

    def single(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(state: TrainState, batch) -> tuple[TrainState, dict]:
        if grad_accum == 1:
            loss, grads = single(state.params, batch)
            if grad_barrier:
                grads = jax.lax.optimization_barrier(grads)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum, -1) + x.shape[1:]), batch)

            def body(carry, mb):
                loss_acc, g_acc = carry
                loss, g = single(state.params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (loss_acc + loss, g_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(body, (0.0, g0), micro)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)

        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = opt_lib.apply_updates(state.params, updates)
        metrics = {"loss": loss,
                   "grad_norm": opt_lib.global_norm(grads),
                   "step": state.step + 1}
        return TrainState(params, opt_state, state.step + 1), metrics

    return step


@dataclasses.dataclass
class Watchdog:
    """Step-time watchdog: flags stragglers (steps slower than
    `threshold` x trailing-median). Persistent flags are the signal for an
    elastic restart (launcher policy; see DESIGN.md SS6)."""

    threshold: float = 3.0
    window: int = 32
    _times: list = dataclasses.field(default_factory=list)
    slow_steps: int = 0

    def observe(self, dt: float) -> bool:
        import statistics
        self._times.append(dt)
        self._times = self._times[-self.window:]
        if len(self._times) < 5:
            return False
        med = statistics.median(self._times[:-1])
        slow = dt > self.threshold * med
        if slow:
            self.slow_steps += 1
        return slow


def train_loop(state: TrainState, step_fn, data_iter, *, n_steps: int,
               ckpt_dir: str | None = None, ckpt_every: int = 100,
               log_every: int = 10, metadata: dict | None = None,
               fail_at_step: int | None = None,
               log_fn: Callable[[str], None] = print) -> TrainState:
    """Run `n_steps` with periodic checkpoints and watchdog.

    fail_at_step: raise a simulated failure once at the given step (the
    launcher's recovery path restarts from the latest checkpoint;
    see launch/train.py and tests/test_fault_tolerance.py).
    """
    watchdog = Watchdog()
    step_jit = jax.jit(step_fn, donate_argnums=(0,))
    start = int(state.step)
    for i in range(start, n_steps):
        batch = next(data_iter)
        t0 = time.perf_counter()
        if fail_at_step is not None and i == fail_at_step:
            raise RuntimeError(f"simulated worker failure at step {i}")
        state, metrics = step_jit(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        if watchdog.observe(dt):
            log_fn(f"[watchdog] step {i} took {dt:.3f}s "
                   f"(>{watchdog.threshold}x median) -- straggler suspect")
        if (i + 1) % log_every == 0:
            log_fn(f"step {i+1}: loss={float(metrics['loss']):.4f} "
                   f"gnorm={float(metrics['grad_norm']):.3f} dt={dt*1e3:.1f}ms")
        if ckpt_dir and (i + 1) % ckpt_every == 0:
            ckpt_lib.save(ckpt_dir, i + 1, state, metadata)
            ckpt_lib.prune(ckpt_dir, keep=3)
    return state
