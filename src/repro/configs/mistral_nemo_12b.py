"""mistral-nemo-12b: 40L d_model=5120 32H (GQA kv=8) d_head=128 d_ff=14336
vocab=131072, 128k ctx. [hf:mistralai/Mistral-Nemo-Base-2407; hf]"""

import jax.numpy as jnp

from repro.configs import base
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="mistral-nemo-12b", n_layers=40, d_model=5120, n_heads=32,
        n_kv_heads=8, d_head=128, d_ff=14336, vocab=131072,
        rope_theta=1000000.0, dtype=jnp.bfloat16)


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="nemo-smoke", n_layers=2, d_model=128, n_heads=8,
        n_kv_heads=2, d_head=16, d_ff=352, vocab=512,
        dtype=jnp.float32, max_seq=64, attn_chunk=32)


base.register(base.ArchSpec(
    arch_id="mistral-nemo-12b", family="lm", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=base.LM_SHAPES,
    tp_heads=True, train_grad_accum=2,
    source="hf:mistralai/Mistral-Nemo-Base-2407",
    notes="dense 12B; TP+FSDP; long_500k extrapolates its 128k ctx "
          "(structurally identical decode)"))
