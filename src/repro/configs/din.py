"""din: embed_dim=18, behaviour seq_len=100, attention MLP 80-40,
main MLP 200-80, target attention. [arXiv:1706.06978]

Field 0 is the 20M-item vocabulary (history + target share it); two profile
fields (100k, 10k).
"""

from repro.configs import base
from repro.models.embedding import EmbeddingConfig
from repro.models.recsys import DINConfig


def make_config() -> DINConfig:
    return DINConfig(
        name="din",
        embedding=EmbeddingConfig(
            vocab_sizes=(20_000_000, 100_000, 10_000), dim=18),
        seq_len=100, attn_mlp=(80, 40), mlp_dims=(200, 80))


def make_smoke_config() -> DINConfig:
    return DINConfig(
        name="din-smoke",
        embedding=EmbeddingConfig(vocab_sizes=(2000, 100, 50), dim=8),
        seq_len=16, attn_mlp=(16, 8), mlp_dims=(32, 16))


base.register(base.ArchSpec(
    arch_id="din", family="recsys", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=base.RECSYS_SHAPES,
    source="arXiv:1706.06978",
    notes="retrieval_cand re-runs target attention per candidate (inherent "
          "to DIN scoring)"))
