"""Cell builder: (arch x shape x mesh) -> jit-able step + abstract inputs +
shardings. The dry-run lowers/compiles exactly what this module returns; the
real launcher (launch/train.py / launch/serve.py) calls the same builders with
concrete arrays, so the dry-run proves the production path.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import base as cfg_base
from repro.dist import policy as pol
from repro.models import embedding as emb_lib
from repro.models import gat as gat_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tf_lib
from repro.train import optimizer as opt_lib
from repro.train.trainer import TrainState, make_train_step


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    step: Callable                       # positional-args step function
    abstract_args: tuple                 # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any                   # pytree or None (auto)
    note: str = ""
    cost_scale: float = 1.0              # multiply reported costs (serving
    #                                      steps chunked via lax.map have the
    #                                      map body counted once)


def _shardings(mesh: Mesh, spec_tree):
    if mesh is None:
        return None
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def default_optimizer(family: str = "recsys"):
    if family == "lm":
        # Factored second moment: 132B-param AdamW f32 m+v would be
        # 8.25 GB/chip at 256 chips -- doesn't leave room for activations.
        return opt_lib.chain(opt_lib.clip_by_global_norm(1.0),
                             opt_lib.adafactor(3e-4))
    return opt_lib.chain(opt_lib.clip_by_global_norm(1.0),
                         opt_lib.adamw(3e-4, weight_decay=0.01))


_WRAPPER_KEYS = {"m", "v", "r", "c", "full", "step", "residual", "inner",
                 "mom"}


def opt_state_specs(opt_state_shape, param_specs):
    """PartitionSpec tree for optimizer state, derived from param specs.

    Optimizer-state leaves mirror parameter paths wrapped in bookkeeping
    keys ('v', 'm', chain indices, ...). Factored Adafactor stats drop the
    last ('r') / second-to-last ('c') dimension of the parameter spec.
    """
    def lookup(tree, keys):
        node = tree
        for k in keys:
            if isinstance(node, dict) and k in node:
                node = node[k]
            elif isinstance(node, (list, tuple)) and isinstance(k, int) \
                    and k < len(node):
                node = node[k]
            else:
                return None
        return node if isinstance(node, P) else None

    flat, tdef = jax.tree_util.tree_flatten_with_path(opt_state_shape)
    specs = []
    for path, leaf in flat:
        keys = []
        for e in path:
            if hasattr(e, "key"):
                keys.append(e.key)
            elif hasattr(e, "idx"):
                keys.append(e.idx)
        # strip wrapper keys / chain indices, keep the param path
        param_keys = [k for k in keys
                      if not (isinstance(k, int) or k in _WRAPPER_KEYS)]
        pspec = lookup(param_specs, param_keys)
        if pspec is None:
            specs.append(P())
            continue
        rank = len(leaf.shape)
        entries = list(pspec) + [None] * (len(leaf.shape) + 2 - len(pspec))
        tail = keys[-1]
        if tail == "r":
            specs.append(P(*entries[:rank]))
        elif tail == "c":
            ent = entries[:rank + 1]
            specs.append(P(*(ent[:-2] + ent[-1:])))
        else:
            specs.append(P(*entries[:rank]))
    return jax.tree_util.tree_unflatten(tdef, specs)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_rules(arch: cfg_base.ArchSpec, kind: str, mesh: Mesh,
              long_ctx: bool = False) -> dict[str, P]:
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    tp = "model"
    n_dev = int(np.prod(list(mesh.shape.values())))
    if kind in ("train", "prefill"):
        pure = arch.pure_dp_train and kind == "train" and n_dev == 256
        rules = pol.lm_rules(dp, tp, pure_dp=pure)
        if not arch.tp_heads and not pure:
            rules["act_bhsd"] = P(dp, None, None, None)
        return rules
    # decode: batch over dp, KV seq over tp (over everything for long ctx)
    kv_seq = (dp + (tp,)) if long_ctx else (tp,)
    batch = None if long_ctx else dp
    rules = pol.lm_rules(dp, tp, pure_dp=False)
    rules.update({
        "act_btd": P(batch, None, None),
        "act_btf": P(batch, None, tp),
        "act_bhsd": P(batch, tp if arch.tp_heads else None, None, None),
        "logits": P(batch, None, tp),
        "kv_cache": P(None, batch, None, kv_seq, None),
    })
    return rules


def _zero1_opt_specs(state_shape, mesh) -> Any:
    """ZeRO-1: optimizer-state leaves sharded on their first dim divisible
    by the full device count; everything else replicated."""
    n_dev = int(np.prod(list(mesh.shape.values())))
    axes = tuple(mesh.axis_names)

    def spec(leaf):
        for i, d in enumerate(leaf.shape):
            if d % n_dev == 0 and d > 0:
                return P(*([None] * i + [axes]))
        return P()

    return jax.tree.map(spec, state_shape)


def build_lm_cell(arch: cfg_base.ArchSpec, shape: cfg_base.ShapeSpec,
                  mesh: Mesh | None, cost_layers: int | None = None,
                  variant: str = "") -> Cell:
    """cost_layers: build an unrolled reduced-depth variant for XLA cost
    extraction (cost_analysis counts a scan body once; the dry-run
    extrapolates affine-in-L from L=1 and L=2 unrolled lowerings).

    variant="zero1": pure-DP over every mesh axis with replicated params and
    device-count-sharded optimizer state (ZeRO-1) -- the SSPerf experiment
    for small dense models (single-pod train only)."""
    dims = shape.dims
    seq, batch = dims["seq_len"], dims["global_batch"]
    cfg = arch.make_config()
    long_ctx = shape.name.startswith("long")
    if shape.kind == "decode":
        cfg = dataclasses.replace(cfg, max_seq=seq)
    loss_chunk = 512
    if cost_layers is not None:
        # unrolled, single-trip attention & loss chunks: every flop visible
        cfg = dataclasses.replace(cfg, n_layers=cost_layers,
                                  scan_layers=False, attn_chunk=seq)
        loss_chunk = seq * batch        # single chunk: no hidden trip counts

    if variant == "zero1" and mesh is not None:
        assert shape.kind == "train"
        dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
        rules = pol.lm_rules(dp, "model", pure_dp=True)
        loss_chunk = seq * batch        # B_local=1: batch chunking is moot
    else:
        rules = _lm_rules(arch, shape.kind, mesh, long_ctx) if mesh else {}
    policy = pol.ShardingPolicy(mesh=mesh, rules=rules)
    pspecs = tf_lib.param_specs(cfg, policy) if mesh else None
    params_shape = jax.eval_shape(
        functools.partial(tf_lib.init_params, cfg=cfg), jax.random.key(0))
    dp = policy.dp_axes()

    if shape.kind == "train":
        optimizer = default_optimizer("lm")
        state_shape = jax.eval_shape(
            lambda p: TrainState(p, optimizer.init(p),
                                 jnp.zeros((), jnp.int32)), params_shape)
        loss = functools.partial(tf_lib.lm_loss, cfg=cfg, policy=policy,
                                 loss_chunk=loss_chunk)
        accum = 1 if cost_layers is not None else arch.train_grad_accum
        step = make_train_step(lambda p, b: loss(p, b), optimizer,
                               grad_accum=accum,
                               grad_barrier=(variant == "zero1"))
        tok_spec = rules["act_btd"][0] if mesh else None
        batch_specs = {"tokens": P(tok_spec, None),
                       "labels": P(tok_spec, None)} if mesh else None
        state_specs = TrainState(
            pspecs, opt_state_specs(state_shape.opt_state, pspecs),
            P()) if mesh else None
        abstract = (state_shape,
                    {"tokens": _sds((batch, seq), jnp.int32),
                     "labels": _sds((batch, seq), jnp.int32)})
        return Cell(arch.arch_id, shape.name, step, abstract,
                    (_shardings(mesh, state_specs),
                     _shardings(mesh, batch_specs)),
                    (_shardings(mesh, state_specs), None))

    if shape.kind == "prefill":
        def step(params, tokens):
            return tf_lib.prefill(params, tokens, cfg, policy)
        batch_spec = P(dp, None) if mesh else None
        out_specs = ((P(dp, "model") if mesh else None),
                     {"k": rules.get("kv_cache"), "v": rules.get("kv_cache"),
                      "length": P()} if mesh else None)
        abstract = (params_shape, _sds((batch, seq), jnp.int32))
        return Cell(arch.arch_id, shape.name, step, abstract,
                    (_shardings(mesh, pspecs), _shardings(mesh, batch_spec)),
                    _shardings(mesh, out_specs))

    # decode
    def step(params, cache, tokens):
        return tf_lib.decode_step(params, cache, tokens, cfg, policy)

    cache_shape = jax.eval_shape(
        functools.partial(tf_lib.init_cache, cfg, batch))
    kv = rules.get("kv_cache") if mesh else None
    cache_specs = {"k": kv, "v": kv, "length": P()} if mesh else None
    tok_spec = (P(dp) if not long_ctx else P()) if mesh else None
    logits_spec = P(rules["logits"][0], rules["logits"][2]) if mesh else None
    abstract = (params_shape, cache_shape, _sds((batch,), jnp.int32))
    return Cell(arch.arch_id, shape.name, step, abstract,
                (_shardings(mesh, pspecs), _shardings(mesh, cache_specs),
                 _shardings(mesh, tok_spec)),
                (_shardings(mesh, logits_spec), _shardings(mesh, cache_specs)),
                note=shape.note)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def build_gnn_cell(arch: cfg_base.ArchSpec, shape: cfg_base.ShapeSpec,
                   mesh: Mesh | None, variant: str = "") -> Cell:
    dims = shape.dims
    cfg = dataclasses.replace(arch.make_config(), d_in=dims["d_feat"],
                              n_classes=dims["n_classes"])
    if variant == "dst_partitioned":
        cfg = dataclasses.replace(cfg, agg_mode="dst_partitioned")
        # node count padded to a device multiple so every shard owns an
        # equal node range (the loader pads in production)
        dims = dict(dims)
        dims["n_nodes"] = -(-dims["n_nodes"] // 512) * 512
    policy = pol.ShardingPolicy(mesh=mesh, rules={})
    n, e = dims["n_nodes"], dims["n_edges"]

    graph_shape = {
        "x": _sds((n, dims["d_feat"]), jnp.float32),
        "src": _sds((e,), jnp.int32),
        "dst": _sds((e,), jnp.int32),
        "edge_mask": _sds((e,), jnp.bool_),
    }
    all_axes = tuple(mesh.axis_names) if mesh else None
    graph_specs = {
        "x": P(), "src": P(all_axes), "dst": P(all_axes),
        "edge_mask": P(all_axes),
    } if mesh else None
    if "n_graphs" in dims:
        graph_shape["graph_id"] = _sds((n,), jnp.int32)
        graph_shape["graph_labels"] = _sds((dims["n_graphs"],), jnp.int32)
        if mesh:
            graph_specs["graph_id"] = P()
            graph_specs["graph_labels"] = P()
    else:
        graph_shape["labels"] = _sds((n,), jnp.int32)
        graph_shape["label_mask"] = _sds((n,), jnp.bool_)
        if mesh:
            graph_specs["labels"] = P()
            graph_specs["label_mask"] = P()

    optimizer = default_optimizer()
    params_shape = jax.eval_shape(
        functools.partial(gat_lib.init_params, cfg=cfg), jax.random.key(0))
    state_shape = jax.eval_shape(
        lambda p: TrainState(p, optimizer.init(p), jnp.zeros((), jnp.int32)),
        params_shape)
    pspec = jax.tree.map(lambda _: P(), params_shape)
    state_specs = TrainState(pspec, ((), {"m": pspec, "v": pspec,
                                          "step": P()}), P()) if mesh else None

    loss = functools.partial(gat_lib.loss_fn, cfg=cfg, policy=policy)
    step = make_train_step(lambda p, b: loss(p, b), optimizer)
    return Cell(arch.arch_id, shape.name, step,
                (state_shape, graph_shape),
                (_shardings(mesh, state_specs), _shardings(mesh, graph_specs)),
                (_shardings(mesh, state_specs), None), note=shape.note)


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------


def _recsys_batch(arch: cfg_base.ArchSpec, cfg, batch: int, dp):
    """(abstract batch pytree, spec pytree) for ranking-model inputs."""
    if arch.arch_id in ("deepfm", "xdeepfm"):
        shp = {"sparse": _sds((batch, cfg.embedding.n_fields), jnp.int32),
               "label": _sds((batch,), jnp.float32)}
        spec = {"sparse": P(dp, None), "label": P(dp)}
    elif arch.arch_id == "din":
        shp = {"hist": _sds((batch, cfg.seq_len), jnp.int32),
               "hist_mask": _sds((batch, cfg.seq_len), jnp.bool_),
               "target": _sds((batch,), jnp.int32),
               "profile": _sds((batch, cfg.embedding.n_fields - 1),
                               jnp.int32),
               "label": _sds((batch,), jnp.float32)}
        spec = {"hist": P(dp, None), "hist_mask": P(dp, None),
                "target": P(dp), "profile": P(dp, None), "label": P(dp)}
    else:  # two-tower
        shp = {"user_feats": _sds((batch, cfg.user_embedding.n_fields),
                                  jnp.int32),
               "item_feats": _sds((batch, cfg.item_embedding.n_fields),
                                  jnp.int32),
               "log_q": _sds((batch,), jnp.float32)}
        spec = {"user_feats": P(dp, None), "item_feats": P(dp, None),
                "log_q": P(dp)}
    return shp, spec


def _recsys_fns(arch: cfg_base.ArchSpec, cfg, policy):
    if arch.arch_id in ("deepfm", "xdeepfm"):
        init = functools.partial(rec_lib.init_ctr_params, cfg=cfg,
                                 table_pad=policy.model_axis_size)
        loss = functools.partial(rec_lib.ctr_loss, cfg=cfg, policy=policy)
        fwd = functools.partial(rec_lib.ctr_forward, cfg=cfg, policy=policy)
        tables = ("table",)
    elif arch.arch_id == "din":
        init = functools.partial(rec_lib.init_din_params, cfg=cfg,
                                 table_pad=policy.model_axis_size)
        loss = functools.partial(rec_lib.din_loss, cfg=cfg, policy=policy)
        fwd = functools.partial(rec_lib.din_forward, cfg=cfg, policy=policy)
        tables = ("table",)
    else:
        init = functools.partial(rec_lib.init_twotower_params, cfg=cfg,
                                 table_pad=policy.model_axis_size)
        loss = functools.partial(rec_lib.twotower_loss, cfg=cfg,
                                 policy=policy)
        fwd = None
        tables = ("user_table", "item_table")
    return init, loss, fwd, tables


def _recsys_param_specs(params_shape, tables, mesh):
    def spec_for(path_key, leaf):
        if path_key in tables:
            return P("model", None)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        top = str(path[0].key) if hasattr(path[0], "key") else ""
        specs.append(spec_for(top, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


def build_recsys_cell(arch: cfg_base.ArchSpec, shape: cfg_base.ShapeSpec,
                      mesh: Mesh | None) -> Cell:
    cfg = arch.make_config()
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape) if mesh else None
    rules = {"act_btd": P(dp, None, None)} if mesh else {}
    policy = pol.ShardingPolicy(mesh=mesh, rules=rules)
    init, loss, fwd, tables = _recsys_fns(arch, cfg, policy)
    params_shape = jax.eval_shape(init, jax.random.key(0))
    pspecs = _recsys_param_specs(params_shape, tables, mesh) if mesh else None

    if shape.kind == "train":
        batch = shape.dims["batch"]
        optimizer = default_optimizer()
        state_shape = jax.eval_shape(
            lambda p: TrainState(p, optimizer.init(p),
                                 jnp.zeros((), jnp.int32)), params_shape)
        step = make_train_step(lambda p, b: loss(p, b), optimizer)
        bshape, bspec = _recsys_batch(arch, cfg, batch, dp)
        state_specs = TrainState(
            pspecs, opt_state_specs(state_shape.opt_state, pspecs),
            P()) if mesh else None
        return Cell(arch.arch_id, shape.name, step, (state_shape, bshape),
                    (_shardings(mesh, state_specs),
                     _shardings(mesh, bspec if mesh else None)),
                    (_shardings(mesh, state_specs), None))

    if shape.kind == "serve":
        batch = shape.dims["batch"]
        bshape, bspec = _recsys_batch(arch, cfg, batch, dp)
        bshape.pop("label", None)
        bspec.pop("label", None) if mesh else None
        if arch.arch_id == "two-tower-retrieval":
            bshape.pop("log_q", None)
            if mesh:
                bspec.pop("log_q", None)

            def step(params, b):
                u = rec_lib.user_tower(params, b["user_feats"], cfg, policy)
                v = rec_lib.item_tower(params, b["item_feats"], cfg, policy)
                return jnp.sum(u * v, axis=-1)
        else:
            def step(params, b):
                return fwd(params, b)
        return Cell(arch.arch_id, shape.name, step, (params_shape, bshape),
                    (_shardings(mesh, pspecs),
                     _shardings(mesh, bspec if mesh else None)),
                    _shardings(mesh, P(dp) if mesh else None))

    # retrieval_cand
    return _build_retrieval_cell(arch, shape, mesh, cfg, policy, params_shape,
                                 pspecs, fwd)


N_RETRIEVE = 100          # top-k returned by retrieval serving
CAND_PAD = 1 << 20        # 1M candidates padded to 2^20 for even sharding


def _build_retrieval_cell(arch, shape, mesh, cfg, policy, params_shape,
                          pspecs, fwd) -> Cell:
    n_cand = shape.dims["n_candidates"]
    dp = policy.dp_axes() if mesh else None

    if arch.arch_id == "two-tower-retrieval":
        # Candidates pre-embedded offline; score 1 query against 1M vectors,
        # sharded over the whole mesh; exact mode (see launch/serve.py for
        # the SAH sketch mode -- dry-run cell variant "retrieval_cand_sah").
        all_axes = tuple(mesh.axis_names) if mesh else None

        def step(params, user_feats, cand_vecs):
            u = rec_lib.user_tower(params, user_feats, cfg, policy)[0]

            if mesh is None:
                scores = cand_vecs @ u
                return jax.lax.top_k(scores, N_RETRIEVE)

            def local(u_l, cands_l):
                scores = cands_l @ u_l                      # (N_l,)
                vals, idx = jax.lax.top_k(scores, N_RETRIEVE)
                rank = jax.lax.axis_index(all_axes)
                gidx = idx + rank * cands_l.shape[0]
                vals = jax.lax.all_gather(vals, all_axes, tiled=True)
                gidx = jax.lax.all_gather(gidx, all_axes, tiled=True)
                best, pos = jax.lax.top_k(vals, N_RETRIEVE)
                return best, jnp.take(gidx, pos)

            return jax.shard_map(
                local, mesh=mesh, in_specs=(P(), P(all_axes, None)),
                out_specs=(P(), P()), check_vma=False)(u, cand_vecs)

        n_pad = CAND_PAD if mesh else n_cand
        abstract = (params_shape,
                    _sds((1, cfg.user_embedding.n_fields), jnp.int32),
                    _sds((n_pad, cfg.out_dim), jnp.float32))
        in_sh = (_shardings(mesh, pspecs), _shardings(mesh, P()),
                 _shardings(mesh, P(tuple(mesh.axis_names), None))
                 if mesh else None)
        return Cell(arch.arch_id, shape.name, step, abstract, in_sh,
                    _shardings(mesh, (P(), P())) if mesh else None,
                    note="exact MIPS baseline; SAH sketch variant is the "
                         "paper-technique cell (dryrun --sah)")

    # Ranking models: bulk-score n_cand candidates for one user context,
    # micro-chunked over the batch: xDeepFM's CIN feature maps at 62.5k
    # rows/device blow past HBM; 4 sequential chunks keep peak residency
    # at serve_bulk levels. (lax.map body is counted once by cost_analysis;
    # cost_scale corrects the roofline record.)
    from repro.configs.base import ShapeSpec
    n_chunks = 4
    bulk = ShapeSpec("serve_bulk", "serve", {"batch": n_cand // n_chunks})
    inner = build_recsys_cell(arch, bulk, mesh)

    def chunked_step(params, b):
        def reshape_pin(x):
            y = x.reshape((n_chunks, x.shape[0] // n_chunks) + x.shape[1:])
            if mesh is not None:
                # pin batch sharding to the chunk-row dim: otherwise GSPMD
                # may split the dp axes across (chunk, row) and the scanned
                # chunk axis ends up sharded (forcing gathers per step)
                spec = P(None, dp, *([None] * (x.ndim - 1)))
                y = jax.lax.with_sharding_constraint(
                    y, NamedSharding(mesh, spec))
            return y

        chunked = jax.tree.map(reshape_pin, b)
        return jax.lax.map(lambda mb: inner.step(params, mb),
                           chunked).reshape(-1)

    bshape, bspec = _recsys_batch(arch, cfg, n_cand, dp)
    bshape.pop("label", None)
    if mesh:
        bspec.pop("label", None)
    cell = Cell(arch.arch_id, shape.name, chunked_step,
                (params_shape, bshape),
                (inner.in_shardings[0],
                 _shardings(mesh, bspec if mesh else None)),
                _shardings(mesh, P(dp) if mesh else None),
                note="retrieval_cand = bulk scoring of 1M candidate rows "
                     "against one user context, lax.map'd in 4 chunks for "
                     "HBM residency",
                cost_scale=float(n_chunks))
    return cell


# ---------------------------------------------------------------------------


def build_cell(arch_id: str, shape_name: str, mesh: Mesh | None) -> Cell:
    arch = cfg_base.get(arch_id)
    shape = arch.shape(shape_name)
    if arch.family == "lm":
        return build_lm_cell(arch, shape, mesh)
    if arch.family == "gnn":
        return build_gnn_cell(arch, shape, mesh)
    return build_recsys_cell(arch, shape, mesh)
