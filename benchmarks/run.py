"""Benchmark driver: one harness per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV. Scale with --scale {smoke,bench}.
``--json PATH`` additionally writes the rows plus environment metadata as
JSON — the format of the checked-in perf baselines (BENCH_rkmips.json):

    PYTHONPATH=src python -m benchmarks.run --scale smoke \
        --only rkmips,artifact,serving,kernels --host-devices 8 \
        --json BENCH_rkmips.json

``--host-devices N`` forces an N-device host (CPU) backend before jax
initializes, which turns on the mesh-sharded build columns of the rkmips
suite (engine/build.py) on a single machine.
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import sys
import time


def _row_to_json(row: str) -> dict:
    name, us, derived = row.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=("smoke", "bench"), default="bench")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: rkmips,artifact,serving,"
                         "load,adversarial,kmips,params,kernels,roofline")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows + run metadata as JSON")
    ap.add_argument("--host-devices", type=int, default=None, metavar="N",
                    help="force N host (CPU) devices before jax "
                         "initializes — enables the mesh-sharded build "
                         "columns of the rkmips suite on one machine")
    args = ap.parse_args()

    if args.host_devices:
        # must land before the first jax import (pulled in transitively by
        # the benchmarks import below)
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count"
              f"={args.host_devices}").strip()

    from benchmarks import (bench_adversarial, bench_artifact,
                            bench_kernels, bench_kmips, bench_load,
                            bench_params, bench_rkmips, bench_roofline,
                            bench_serving)

    small = args.scale == "smoke"
    suites = {
        "rkmips": lambda: bench_rkmips.run(
            n=2048 if small else 8192, m=4096 if small else 16384,
            nq=8 if small else 16,
            ks=(1, 10, 50) if small else (1, 5, 10, 20, 30, 40, 50)),
        "artifact": lambda: bench_artifact.run(
            n=2048 if small else 8192, m=4096 if small else 16384,
            nq=8 if small else 16, cap=128 if small else 256),
        "serving": lambda: bench_serving.run(
            n=2048 if small else 8192, m=4096 if small else 16384,
            nq=8 if small else 16, cap=128 if small else 256,
            steady_rounds=48 if small else 128),
        "load": lambda: bench_load.run(
            n=2048 if small else 8192, m=4096 if small else 16384,
            nq=8 if small else 16, cap=128 if small else 256,
            duration=3.0 if small else 10.0,
            rates=(16.0, 48.0) if small else (32.0, 96.0)),
        "adversarial": lambda: bench_adversarial.run(
            n=2048 if small else 8192, m=4096 if small else 16384,
            nq=8 if small else 16,
            rate=24.0 if small else 48.0,
            duration=3.0 if small else 10.0),
        "kmips": lambda: bench_kmips.run(
            n=4096 if small else 16384, m=4096 if small else 16384,
            nq=8 if small else 32,
            ks=(1, 10, 50) if small else (1, 5, 10, 20, 30, 40, 50)),
        "params": lambda: bench_params.run(
            n=2048 if small else 4096, m=4096 if small else 8192,
            nq=4 if small else 8),
        "kernels": lambda: bench_kernels.run(n=8192 if small else 65536),
        "roofline": bench_roofline.run,
    }
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    all_rows: list[str] = []
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        t0 = time.time()
        try:
            for row in fn():
                print(row, flush=True)
                all_rows.append(row)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            raise
        print(f"# suite {name} done in {time.time()-t0:.1f}s",
              file=sys.stderr)

    if args.json:
        import jax
        doc = {
            "meta": {
                "date": datetime.date.today().isoformat(),
                "scale": args.scale,
                "suites": sorted(suites),
                "jax": jax.__version__,
                "backend": jax.default_backend(),
                "device_count": jax.device_count(),
            },
            "rows": [_row_to_json(r) for r in all_rows],
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"# wrote {args.json} ({len(all_rows)} rows)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
