"""Multi-tenant gateway contracts (engine/gateway.py, DESIGN.md SS15).

Pins the gateway tier's guarantees: (1) routing adds nothing — a tenant's
answers are bitwise the dedicated per-tenant runtime's (and the one-shot
batched engine's) on the same queries; (2) tenants sharing a dispatch
signature share one compiled-trace cache — after a gateway-wide warmup,
traffic from every such tenant adds zero traces (``scan_budget`` is a
traced operand, so budgeted and unbudgeted tenants share executables);
(3) scan budgets truncate *visibly and conservatively*: a budgeted answer
never adds a user the unbudgeted answer lacks, exhausted tickets come back
``truncated=True`` with a funnel snapshot, and ``RuntimeStats.truncated``
attributes them to the right tenant; (4) one tenant's held dispatch lock
(a swap, a compaction landing, a slow flush) never stalls another
tenant's traffic — the pool skips locked tenants; (5) admission rejects
with explicit messages (unknown tenant, k over ``max_k``,
``max_in_flight`` reached); (6) per-tenant stats never cross tenants.

Threading discipline (CONTRIBUTING): every blocking wait carries an
explicit timeout, and any lock/gate taken by the test is released in
``finally`` so a failing assert can never wedge the pool threads.
"""

import jax
import numpy as np
import pytest

from repro.data import synthetic
from repro.engine import (IndexArtifact, RkMIPSEngine, ServingGateway,
                          ServingRuntime, TenantPolicy, get_config)

D = 16


def _cfg():
    # chunk=8 keeps the execute loop multi-chunk on this workload, so a
    # small scan_budget actually bites (truncation is exercised, not
    # just plumbed)
    return get_config("sah").replace(tile=32, n_bits=32, k_max=8, n_top=8,
                                     leaf_size=8, n_cand=16, scan="sketch",
                                     delta_capacity=8, serve_batch_size=4,
                                     chunk=8)


_BUILD_KEY = jax.random.PRNGKey(31)


@pytest.fixture(scope="module")
def workload():
    key = jax.random.PRNGKey(23)
    ki, kq = jax.random.split(key)
    items, users = synthetic.recommendation_data(ki, 120, 64, D)
    queries = synthetic.queries_from_items(kq, items, 12)
    return items, users, queries


@pytest.fixture(scope="module")
def artifact(workload):
    items, users, _ = workload
    return IndexArtifact.build(items, users, _BUILD_KEY, config=_cfg())


def _results(tickets, timeout=60):
    return [t.result(timeout=timeout) for t in tickets]


# -- (1) routing is bitwise-invisible ------------------------------------


def test_gateway_answers_match_dedicated_runtime_bitwise(workload, artifact):
    """THE tier contract: the same queries through gateway.submit and
    through a dedicated ServingRuntime (and the one-shot batched engine)
    resolve bitwise identically, ticket for ticket."""
    _, _, queries = workload
    ref = RkMIPSEngine.from_artifact(artifact).query_batch(queries, 3)
    with RkMIPSEngine.from_artifact(artifact).async_reverse_server(k=3) \
            as dedicated:
        ded = _results([dedicated.submit(queries[i])
                        for i in range(queries.shape[0])])
    with ServingGateway(pool_workers=2) as gw:
        gw.register("t", artifact, k=3)
        got = _results([gw.submit("t", queries[i])
                        for i in range(queries.shape[0])])
    for i, (g, d) in enumerate(zip(got, ded)):
        np.testing.assert_array_equal(np.asarray(g.predictions),
                                      np.asarray(d.predictions))
        np.testing.assert_array_equal(np.asarray(g.predictions),
                                      np.asarray(ref.predictions[i]))
        assert g.truncated is False and d.truncated is False


def test_routing_follows_fingerprints(workload, artifact):
    _, _, queries = workload
    with ServingGateway() as gw:
        gw.register("t", artifact, k=3)
        assert gw.route("t") == artifact.fingerprint
        art2 = gw.insert_items("t", queries[:2])
        assert gw.route("t") == art2.fingerprint != artifact.fingerprint
        gw.swap("t", artifact)
        assert gw.route("t") == artifact.fingerprint
        assert gw.runtime("t").stats.swaps == 2


# -- (2) one trace cache across tenants ----------------------------------


def test_shared_signature_tenants_add_zero_traces_after_warmup(
        workload, artifact):
    """Two tenants with identical (rung, k) signatures — one budgeted,
    one not — share one compiled dispatch: gateway-wide warmup traces
    each cell once, and live traffic from BOTH tenants adds nothing."""
    _, _, queries = workload
    with ServingGateway(pool_workers=2) as gw:
        gw.register("plain", artifact, k=3)
        gw.register("budgeted", artifact, k=3,
                    policy=TenantPolicy(scan_budget=2))
        cells = gw.warmup()
        assert cells > 0
        assert gw.stats().traces_after_warmup == 0
        tickets = []
        for i in range(queries.shape[0]):
            tickets.append(gw.submit("plain", queries[i]))
            tickets.append(gw.submit("budgeted", queries[i]))
        _results(tickets)
        st = gw.stats()
        assert st.traces_after_warmup == 0
        for name in ("plain", "budgeted"):
            assert st.tenants[name].traces_after_warmup == 0


def test_shared_dispatch_is_adopted_not_duplicated(workload, artifact):
    """Same config modulo budget -> one _TraceCount object; a genuinely
    different recipe -> its own."""
    items, users, _ = workload
    other = IndexArtifact.build(items, users, _BUILD_KEY,
                                config=_cfg().replace(n_cand=8))
    with ServingGateway() as gw:
        a = gw.register("a", artifact, k=3)
        b = gw.register("b", artifact, k=3,
                        policy=TenantPolicy(scan_budget=1))
        c = gw.register("c", other, k=3)
        assert b.server.engine._traces is a.server.engine._traces
        assert c.server.engine._traces is not a.server.engine._traces


# -- (3) budget truncation: conservative, visible, attributed ------------


def test_budget_truncation_is_conservative_and_flagged(workload, artifact):
    _, _, queries = workload
    ref = RkMIPSEngine.from_artifact(artifact).query_batch(queries, 3)
    with ServingGateway(pool_workers=2) as gw:
        gw.register("plain", artifact, k=3)
        gw.register("tight", artifact, k=3,
                    policy=TenantPolicy(scan_budget=1))
        plain = _results([gw.submit("plain", queries[i])
                          for i in range(queries.shape[0])])
        tight = _results([gw.submit("tight", queries[i])
                          for i in range(queries.shape[0])])
        st = gw.stats()
    truncated = [r for r in tight if r.truncated]
    assert truncated, "chunk=8 + scan_budget=1 must truncate something " \
                      "on this workload (otherwise the test is vacuous)"
    for i, r in enumerate(tight):
        got = np.asarray(r.predictions)
        full = np.asarray(ref.predictions[i])
        # conservative: skipped lanes resolve to "not in the audience" —
        # a budgeted answer never CONTAINS a user the full answer lacks
        assert not np.any(got & ~full)
        if not r.truncated:
            np.testing.assert_array_equal(got, full)
    for r in truncated:
        assert r.funnel is not None
        assert r.funnel.truncated > 0
        assert "budget-truncated" in r.funnel.format()
    # attribution: the budgeted tenant owns every truncation, the plain
    # tenant none (stats isolation for the new counter)
    assert st.tenants["tight"].truncated == len(truncated)
    assert st.tenants["plain"].truncated == 0
    assert all(not r.truncated for r in plain)


def test_generous_budget_is_bitwise_exact(workload, artifact):
    """A budget the scan never exhausts answers bitwise like no budget —
    budget=0 and budget=huge share the executable AND the answers."""
    _, _, queries = workload
    ref = RkMIPSEngine.from_artifact(artifact).query_batch(queries, 3)
    eng = RkMIPSEngine(artifact.config.replace(scan_budget=10_000)) \
        .attach(artifact)
    res = eng.query_batch(queries, 3)
    np.testing.assert_array_equal(np.asarray(res.predictions),
                                  np.asarray(ref.predictions))
    assert int(np.asarray(res.stats.truncated).sum()) == 0


# -- (4) no cross-tenant stalls ------------------------------------------


def test_locked_tenant_never_stalls_another(workload, artifact):
    """Hold tenant A's dispatch lock (what a hot-swap or a landing
    compaction does) while B's traffic flows: B must resolve, with a
    single pool worker, because the pool skips locked tenants instead of
    queueing behind them."""
    _, _, queries = workload
    with ServingGateway(pool_workers=1) as gw:
        a = gw.register("a", artifact, k=3)
        gw.register("b", artifact, k=3)
        assert a._dispatch_lock.acquire(timeout=10)
        try:
            tb = [gw.submit("b", queries[i]) for i in range(4)]
            for t in tb:
                t.result(timeout=60)   # resolves while A stays locked
            ta = gw.submit("a", queries[0])
            assert not ta.done()
        finally:
            a._dispatch_lock.release()
        ta.result(timeout=60)          # A resumes once unlocked


def test_background_compaction_does_not_stall_other_tenants(
        workload, artifact):
    """One tenant compacting (churn past compact_fill -> background
    rebuild -> reconcile -> swap) while another serves: the other
    tenant's tickets keep resolving, and the compaction lands."""
    _, _, queries = workload
    with ServingGateway(pool_workers=1) as gw:
        gw.register("churny", artifact, k=3, compaction=True,
                    compact_fill=0.2, poll_interval=0.01)
        gw.register("steady", artifact, k=3)
        gw.insert_items("churny", queries[:3])
        gw.request_compaction("churny")
        deadline = 60.0
        import time
        end = time.monotonic() + deadline
        while gw.runtime("churny").stats.compactions < 1:
            t = gw.submit("steady", queries[0])
            t.result(timeout=60)
            assert time.monotonic() < end, "compaction never landed"
            time.sleep(0.01)
        st = gw.stats()
        assert st.tenants["churny"].compactions >= 1
        assert st.tenants["steady"].completed >= 1
        assert st.tenants["steady"].compactions == 0
        # post-compaction both tenants still answer
        r1 = gw.submit("churny", queries[1]).result(timeout=60)
        r2 = gw.submit("steady", queries[1]).result(timeout=60)
        assert r1.k == r2.k == 3


# -- (5) admission rejections --------------------------------------------


def test_policy_rejection_messages(workload, artifact):
    _, _, queries = workload
    with ServingGateway() as gw:
        gw.register("t", artifact, k=3,
                    policy=TenantPolicy(max_k=4, max_in_flight=2))
        with pytest.raises(KeyError, match="unknown tenant 'ghost'"):
            gw.submit("ghost", queries[0])
        with pytest.raises(ValueError,
                           match=r"k=6 exceeds policy max_k=4"):
            gw.submit("t", queries[0], k=6)
        with pytest.raises(ValueError, match="already registered"):
            gw.register("t", artifact, k=3)
        rt = gw.runtime("t")
        assert rt._dispatch_lock.acquire(timeout=10)
        try:
            held = [gw.submit("t", queries[i]) for i in range(2)]
            with pytest.raises(RuntimeError,
                               match=r"max_in_flight=2"):
                gw.submit("t", queries[2])
        finally:
            rt._dispatch_lock.release()
        _results(held)
        # capacity frees up once tickets resolve
        gw.submit("t", queries[2]).result(timeout=60)


def test_register_validation(artifact):
    items = artifact.items
    fwd = IndexArtifact.build(items, None, _BUILD_KEY, config=_cfg())
    with ServingGateway() as gw:
        with pytest.raises(ValueError, match="mode='reverse' needs"):
            gw.register("r", fwd, k=3, mode="reverse")
        with pytest.raises(ValueError, match="scan_budget is a "
                                             "reverse-pipeline knob"):
            gw.register("f", fwd, k=3,
                        policy=TenantPolicy(scan_budget=4))
        with pytest.raises(ValueError, match="pool"):
            gw.register("p", artifact, k=3, pool=None)
    with pytest.raises(ValueError, match="max_k must be >= 1"):
        TenantPolicy(max_k=0)
    with pytest.raises(ValueError, match="scan_budget must be >= 0"):
        TenantPolicy(scan_budget=-1)


def test_forward_tenant_serves_through_the_pool(workload, artifact):
    """mode='auto' on a users=None artifact is a forward tenant; its
    pooled answers are bitwise the library-mode flush."""
    items, _, queries = workload
    fwd = IndexArtifact.build(items, None, _BUILD_KEY, config=_cfg())
    from repro.engine import RetrievalServer
    sync = RetrievalServer.from_artifact(fwd)
    sync.submit(queries[:4])
    ref = sync.flush(3)
    with ServingGateway(pool_workers=2) as gw:
        rt = gw.register("fwd", fwd, k=3)
        assert rt.server.__class__ is RetrievalServer
        got = _results([gw.submit("fwd", queries[i]) for i in range(4)])
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(g.values),
                                      np.asarray(r.values))
        np.testing.assert_array_equal(np.asarray(g.ids), np.asarray(r.ids))


# -- (6) stats isolation -------------------------------------------------


def test_stats_are_attributed_per_tenant(workload, artifact):
    _, _, queries = workload
    with ServingGateway(pool_workers=2) as gw:
        gw.register("a", artifact, k=3)
        gw.register("b", artifact, k=3)
        ta = [gw.submit("a", queries[i]) for i in range(8)]
        tb = [gw.submit("b", queries[i]) for i in range(3)]
        _results(ta + tb)
        st = gw.stats()
    assert st.tenants["a"].submitted == st.tenants["a"].completed == 8
    assert st.tenants["b"].submitted == st.tenants["b"].completed == 3
    assert st.tenants["a"].failed == st.tenants["b"].failed == 0


def test_pooled_runtime_close_leaves_pool_serving_others(
        workload, artifact):
    """Closing one tenant's runtime must not tear the shared pool down:
    the surviving tenant keeps answering."""
    _, _, queries = workload
    with ServingGateway(pool_workers=1) as gw:
        gw.register("gone", artifact, k=3)
        gw.register("stay", artifact, k=3)
        gw.submit("gone", queries[0]).result(timeout=60)
        gw.runtime("gone").close(timeout=30)
        gw.submit("stay", queries[0]).result(timeout=60)
        with pytest.raises(RuntimeError, match="closed"):
            gw.submit("gone", queries[0])


def test_standalone_pooled_runtimes_compose_without_gateway(
        workload, artifact):
    """WorkerPool is usable below the gateway: two plain ServingRuntimes
    on one pool dispatch bitwise like dedicated workers."""
    from repro.engine import WorkerPool
    _, _, queries = workload
    ref = RkMIPSEngine.from_artifact(artifact).query_batch(queries[:4], 3)
    with WorkerPool(2) as pool:
        rt1 = ServingRuntime(
            RkMIPSEngine.from_artifact(artifact).reverse_server(),
            k=3, pool=pool)
        rt2 = ServingRuntime(
            RkMIPSEngine.from_artifact(artifact).reverse_server(),
            k=3, pool=pool)
        try:
            r1 = _results([rt1.submit(queries[i]) for i in range(4)])
            r2 = _results([rt2.submit(queries[i]) for i in range(4)])
        finally:
            rt1.close(timeout=30)
            rt2.close(timeout=30)
    for i in range(4):
        np.testing.assert_array_equal(np.asarray(r1[i].predictions),
                                      np.asarray(ref.predictions[i]))
        np.testing.assert_array_equal(np.asarray(r2[i].predictions),
                                      np.asarray(ref.predictions[i]))
