"""Fig. 1 + Fig. 2 + Table 1: RkMIPS query time / F1 vs k, ablation grid,
indexing time -- for SAH, SA-Simpfer, H2-Cone, H2-Simpfer, Simpfer.

Raw H2-ALSH (no user pruning at all) is omitted: the paper shows it 2-3
orders of magnitude slower than every pruned method (Fig. 1); our grid keeps
the informative frontier. All other methods are exact configurations of the
same engine (DESIGN.md SS3), so the comparison isolates exactly the paper's
two contributions (SAT vs QNF; cone vs norm blocking).

Also reports the tentpole cell (DESIGN.md SS9): the flat-queue batched
driver (``query_batch``) against the legacy per-query ``lax.map`` driver
(``query_batch_mapped``), wall time per query and dispatch trace counts,
at several batch sizes. The checked-in baseline lives in BENCH_rkmips.json
(``python -m benchmarks.run --scale smoke --only rkmips --json ...``).
"""

from __future__ import annotations

from benchmarks import common


def _prev_prime(n: int) -> int:
    """Largest prime <= n (n >= 2) — the worst case for every divisibility
    assumption in the stack (tiles, leaves, mesh shard counts)."""
    for c in range(n, 1, -1):
        if all(c % p for p in range(2, int(c ** 0.5) + 1)):
            return c
    return 2


def build_grid(n=2048, d=64, ms=(4096, 16384, 65536), k_max=50,
               n_bits=128):
    """Index-build m-scaling grid: single-device vs mesh-sharded staged
    build (engine/build.py, DESIGN.md SS11).

    One cell per (m, path): total staged-build wall time (warm — the
    second build, so stage compiles are excluded and the cell tracks the
    actual array work) with the per-stage split in ``derived``. The
    sharded columns appear only when the process has a multi-device
    backend (``python -m benchmarks.run --host-devices 8 ...``); their
    ``derived`` records the speedup over the single-device build at the
    same m, and the builds are asserted fingerprint-identical first.

    Caveat for the checked-in baseline: forced host devices all share one
    CPU's cores, and the single-device GEMM already multi-threads across
    them — so on ``--host-devices`` the sharded column measures pure
    sharding overhead (speedup < 1, converging toward parity as m grows
    and the per-shard work amortizes the dispatch). Real speedup needs
    devices with disjoint compute; the cell exists to pin the overhead
    trend and the bitwise-equality check, not to advertise host-CPU wins.
    """
    import jax

    from repro.data import synthetic
    from repro.dist.policy import ShardingPolicy
    from repro.engine import IndexArtifact, get_config

    cfg = get_config("sah").replace(k_max=k_max, n_bits=n_bits)
    policy = None
    if jax.device_count() > 1:
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
        policy = ShardingPolicy(mesh=mesh, rules={})

    rows = []
    for m in ms:
        items, users = synthetic.recommendation_data(
            jax.random.PRNGKey(0), n, m, d, kind="nmf")
        kb = jax.random.PRNGKey(1)

        def timed_build(**kw):
            IndexArtifact.build(items, users, kb, config=cfg, **kw)  # warm
            return IndexArtifact.build(items, users, kb, config=cfg, **kw)

        art = timed_build()
        tm = art.build_timings
        rows.append(common.fmt_row(
            f"table1/build_grid/m={m}/single", tm.total * 1e6,
            f"n={n};d={d};codes={tm.item_codes * 1e6:.0f}us;"
            f"block={tm.user_blocking * 1e6:.0f}us;"
            f"lb={tm.lower_bounds * 1e6:.0f}us"))
        if policy is not None:
            art_s = timed_build(policy=policy)
            assert art_s.fingerprint == art.fingerprint, \
                "sharded build must be fingerprint-identical (DESIGN SS11)"
            tm_s = art_s.build_timings
            rows.append(common.fmt_row(
                f"table1/build_grid/m={m}/sharded", tm_s.total * 1e6,
                f"devices={policy.device_count};"
                f"speedup={tm.total / tm_s.total:.2f};"
                f"lb={tm_s.lower_bounds * 1e6:.0f}us"))
    return rows


def run(n=8192, m=16384, d=64, nq=16, ks=(1, 5, 10, 20, 30, 40, 50),
        build_ms=(4096, 16384, 65536)):
    wl = common.make_workload("nmf", n, m, d, nq, ks)
    rows = []
    for method in common.METHODS:
        eng, t_build = common.build_method(wl, method)
        rows.append(common.fmt_row(
            f"table1/index_time/{method}", t_build * 1e6,
            f"n={n};m={m}"))
        for k in ks:
            dt, f1, stats = common.run_method(wl, eng, k)
            rows.append(common.fmt_row(
                f"fig1/query/{method}/k={k}", dt * 1e6,
                f"f1={f1:.3f};scanned={int(stats.n_scan.mean())}"))

    # Tentpole cell (DESIGN.md SS9): flat-queue batched driver vs the
    # legacy per-query lax.map driver, same engine and index, across batch
    # sizes. ``traces`` pins the compile story per cell (counter deltas):
    # each batch shape costs exactly one trace, never one per query.
    eng, _ = common.build_method(wl, "sah")
    k_mid = ks[len(ks) // 2]
    for nq_cell in sorted({1, max(1, nq // 2), nq}):
        qs = wl.queries[:nq_cell]
        t_flat0 = eng.rkmips_compile_count
        t_map0 = eng.rkmips_mapped_compile_count
        eng.query_batch(qs, k_mid)                       # warm (compile)
        dt_flat = eng.query_batch(qs, k_mid).seconds / nq_cell
        eng.query_batch_mapped(qs, k_mid)
        dt_map = eng.query_batch_mapped(qs, k_mid).seconds / nq_cell
        rows.append(common.fmt_row(
            f"tentpole/batched/k={k_mid}/nq={nq_cell}", dt_flat * 1e6,
            f"traces={eng.rkmips_compile_count - t_flat0};"
            f"speedup_vs_mapped={dt_map / dt_flat:.2f}"))
        rows.append(common.fmt_row(
            f"tentpole/mapped/k={k_mid}/nq={nq_cell}", dt_map * 1e6,
            f"traces={eng.rkmips_mapped_compile_count - t_map0}"))

    # Non-divisible grid cell: prime user/item counts (the sizes the old
    # sharded path rejected; DESIGN.md SS8 pads them with dead rows). One
    # method suffices — the cell tracks padding overhead, not the ablation.
    n_odd, m_odd = _prev_prime(n), _prev_prime(m)
    wl_odd = common.make_workload("nmf", n_odd, m_odd, d, nq, ks[:1])
    eng, t_build = common.build_method(wl_odd, "sah")
    rows.append(common.fmt_row(
        f"table1/index_time/sah-odd", t_build * 1e6,
        f"n={n_odd};m={m_odd}"))
    dt, f1, stats = common.run_method(wl_odd, eng, ks[0])
    rows.append(common.fmt_row(
        f"fig1/query/sah-odd/k={ks[0]}", dt * 1e6,
        f"f1={f1:.3f};scanned={int(stats.n_scan.mean())}"))

    # Index-build m-scaling grid (DESIGN.md SS11): single-device vs
    # mesh-sharded staged build at growing user counts.
    rows.extend(build_grid(n=n, d=d, ms=build_ms))
    return rows
