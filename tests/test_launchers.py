"""Launcher-level tests: the failure-recovery restart loop end to end."""

import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_train_launcher_recovers_from_failure(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    repo = os.path.dirname(os.path.dirname(__file__))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-0.6b",
         "--smoke", "--steps", "12", "--ckpt-dir", str(tmp_path / "ck"),
         "--ckpt-every", "4", "--simulate-failure", "6"],
        env=env, capture_output=True, text=True, timeout=600, cwd=repo)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "worker failure" in out.stdout
    assert "restored step 4" in out.stdout
    assert "training complete at step 12" in out.stdout
