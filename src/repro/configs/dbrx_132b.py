"""dbrx-132b: 40L d_model=6144 48H (GQA kv=8) MoE 16 experts top-4,
d_ff_expert=10752, vocab=100352. [hf:databricks/dbrx-base; unverified]"""

import jax.numpy as jnp

from repro.configs import base
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48,
        n_kv_heads=8, d_head=128, d_ff=10752, vocab=100352,
        moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752),
        rope_theta=500000.0, dtype=jnp.bfloat16)


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="dbrx-132b-smoke", n_layers=2, d_model=128, n_heads=8,
        n_kv_heads=2, d_head=16, d_ff=224, vocab=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=224),
        dtype=jnp.float32, max_seq=64, attn_chunk=32)


base.register(base.ArchSpec(
    arch_id="dbrx-132b", family="lm", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=base.LM_SHAPES,
    tp_heads=True, train_grad_accum=4, source="hf:databricks/dbrx-base",
    notes="fine-grained MoE 16e top-4; EP over 'model' (1 expert/chip); "
          "grad-accum 2 halves activation residency at 132B scale"))
