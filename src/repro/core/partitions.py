"""Norm-range partitioning of item vectors (SA-ALSH indexing, Algorithm 1).

Items are sorted by descending l2-norm and greedily cut into ranges
(b*M_j, M_j] where M_j is the first (largest) norm in partition j. The number
of partitions t is data-dependent; we cap it at a static `max_partitions` and
keep per-partition stats in padded arrays with a validity count.

The greedy recurrence (M_{j+1} = first norm <= b * M_j) is sequential; it runs
as a lax.scan over the sorted norms at index-build time. Per-partition
centroids/radii/max-norms are then computed with segment reductions.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class NormPartitions(NamedTuple):
    """Partition structure over items sorted by descending norm (all padded).

    Attributes:
      part_id:   (n,)  int32, partition index of each sorted item.
      n_parts:   ()    int32, number of valid partitions (<= max_partitions).
      start:     (T,)  int32, first sorted-item index of each partition.
      size:      (T,)  int32, item count of each partition (0 for padding).
      max_norm:  (T,)  f32, M_j = max item norm in partition (0 for padding).
      centroid:  (T,d) f32, c_j = mean of partition items.
      radius:    (T,)  f32, R_j = max ||p - c_j|| over partition items.
    """

    part_id: jnp.ndarray
    n_parts: jnp.ndarray
    start: jnp.ndarray
    size: jnp.ndarray
    max_norm: jnp.ndarray
    centroid: jnp.ndarray
    radius: jnp.ndarray


def assign_partitions(sorted_norms: jnp.ndarray, b: float,
                      max_partitions: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Greedy norm cut. sorted_norms (n,) descending -> (part_id (n,), n_parts).

    Partition j holds items with norm in (b*M_j, M_j]. A new partition opens at
    item i when ||p_i|| <= b * M_current. Partition ids are clamped to
    max_partitions - 1 (the tail partition absorbs the rest; with b=0.5 and
    max_partitions=64 this never triggers in practice since norms would have to
    span 2^63).
    """

    def step(carry, norm):
        cur_max, pid = carry
        open_new = norm <= b * cur_max
        pid = jnp.where(open_new, jnp.minimum(pid + 1, max_partitions - 1), pid)
        cur_max = jnp.where(open_new, norm, cur_max)
        return (cur_max, pid), pid

    init = (sorted_norms[0], jnp.asarray(0, jnp.int32))
    (_, last_pid), part_id = jax.lax.scan(step, init, sorted_norms)
    return part_id.astype(jnp.int32), last_pid + 1


def build_partitions(items_sorted: jnp.ndarray, sorted_norms: jnp.ndarray,
                     b: float, max_partitions: int) -> NormPartitions:
    """Full partition structure for items already sorted by descending norm."""
    n, _ = items_sorted.shape
    part_id, n_parts = assign_partitions(sorted_norms, b, max_partitions)

    ones = jnp.ones((n,), jnp.float32)
    size_f = jax.ops.segment_sum(ones, part_id, num_segments=max_partitions)
    size = size_f.astype(jnp.int32)
    # First index of each partition = exclusive cumsum of sizes.
    start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(size)[:-1]]).astype(jnp.int32)
    max_norm = jax.ops.segment_max(
        sorted_norms, part_id, num_segments=max_partitions)
    max_norm = jnp.where(size > 0, max_norm, 0.0)

    sums = jax.ops.segment_sum(items_sorted, part_id,
                               num_segments=max_partitions)
    centroid = sums / jnp.maximum(size_f, 1.0)[:, None]
    diff = items_sorted - centroid[part_id]
    d2 = jnp.sum(diff * diff, axis=-1)
    radius2 = jax.ops.segment_max(d2, part_id, num_segments=max_partitions)
    radius = jnp.sqrt(jnp.where(size > 0, radius2, 0.0))

    return NormPartitions(part_id=part_id, n_parts=n_parts, start=start,
                          size=size, max_norm=max_norm, centroid=centroid,
                          radius=radius)
