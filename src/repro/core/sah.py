"""SAH: Shifting-aware Asymmetric Hashing for RkMIPS (Algorithms 4-5).

Combines SA-ALSH (core/sa_alsh.py) over items with cone blocking
(core/cone.py) and Simpfer lower bounds (core/simpfer.py) over users.

Indexing (Algorithm 4):
  1. sort items by descending norm; P' = the n_top highest-norm items;
  2. exact lower-bound arrays L_u over P' for every user (batched matmul);
  3. SA-ALSH index over P \\ P';
  4. cone blocks over unit users; block lower bounds L_B = min over leaf.

Query (Algorithm 5), batched over queries AND users in two phases
(plan/execute, DESIGN.md SS9):

  plan (rkmips_plan) -- for every (query, user) pair of the batch:
  1. node-level bound (Lemma 2) kills whole blocks: ub_B < L_B[k-1];
  2. vector-level bound (Lemma 3) kills users: ub_u < L_u[k-1];
  3. tau = <u, q> computed densely (one (m,d) matvec per query -- on TPU
     this is cheaper than gathering survivors; the bounds' value is keeping
     users out of the expensive scan, and we report both pruning stages in
     the stats); "no" if tau < L_u[k-1]; "yes" if tau >= ||p_k|| (k-th
     largest item norm);
  4. the undecided (query, user) pairs of the WHOLE batch are compacted
     into one flat work queue, query-major with cone-leaf order preserved
     within each query (cone order => chunk locality: users in the same
     cone have correlated early-exit depths, so chunks finish together).

  execute (rkmips_execute) -- ONE while_loop drives fixed-size, possibly
  mixed-query chunks of that queue through the counting scan
  decide_count(): each lane carries its own tau and eps, so lanes from a
  fast query never idle next to a slow query's lanes, and batch size is a
  pure throughput knob (compile cost is O(1) in nq -- this is also what
  makes the sharded path trace once, see engine/sharding.py).

The per-query ``rkmips`` driver is retained as the reference oracle; the
batched path is bitwise equal to it, prediction for prediction (the plan
phase lax.maps the *identical* per-query dense math, and decide_count
lanes are chunk-composition-independent).

The same engine gives every paper baseline via two switches:
  user blocking: "cone" (SAH / H2-Cone) or "norm" (Simpfer-style blocks --
     with unit users, Simpfer's norm blocking degenerates to arbitrary
     contiguous blocks; see DESIGN.md)
  item scan: transform "sat" + scan "sketch" (SA-ALSH), transform "qnf"
     (H2-ALSH), scan "exact" (Simpfer's linear scan).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import cone as _cone
from repro.core import sa_alsh as _alsh
from repro.core import simpfer as _simpfer


class SAHIndex(NamedTuple):
    """Everything the query phase needs. Users live in cone-leaf order."""

    alsh: _alsh.SAALSHIndex          # over P \ P'
    users: jnp.ndarray               # (m_pad, d) unit users, leaf order
    user_ids: jnp.ndarray            # (m_pad,) original user row
    user_mask: jnp.ndarray           # (m_pad,) real (non-duplicate) users
    center: jnp.ndarray              # (n_blocks, d)
    omega: jnp.ndarray               # (n_blocks,)
    theta: jnp.ndarray               # (m_pad,)
    user_lb: jnp.ndarray             # (m_pad, kmax)
    block_lb: jnp.ndarray            # (n_blocks, kmax)
    top_norms: jnp.ndarray           # (n_top,) norms of P', descending
    top_items: jnp.ndarray           # (n_top, d) P' item vectors
    top_ids: jnp.ndarray             # (n_top,) original rows of P'

    @property
    def n_blocks(self) -> int:
        return self.center.shape[0]

    @property
    def kmax(self) -> int:
        return self.user_lb.shape[1]

    @property
    def n_users(self) -> int:
        return self.users.shape[0]


# ---------------------------------------------------------------------------
# Build stages (Algorithm 4 as a pipeline).
#
# ``build`` below composes four pure stage functions. engine/build.py
# composes the SAME functions with per-stage timing and optional mesh
# sharding of the row-parallel steps (SRP hashing over items, lower-bound
# rows over users); both compositions are bitwise identical by
# construction. Stage contract: DESIGN.md SS11.
# ---------------------------------------------------------------------------


class NormSplit(NamedTuple):
    """Stage 1 output: items split into P' (top n_top by norm) and the rest.

    ``order`` maps sorted position -> original item row (the argsort of
    descending norm); ``rest`` rows are positions n_top.. of that order.
    """

    order: jnp.ndarray       # (n,) sorted position -> original row
    top_items: jnp.ndarray   # (n_top, d) P' vectors, descending norm
    top_ids: jnp.ndarray     # (n_top,) int32 original rows of P'
    top_norms: jnp.ndarray   # (n_top,) f32 descending
    rest: jnp.ndarray        # (n - n_top, d) remaining items, sorted


class UserBlocking(NamedTuple):
    """Stage 3 output: users blocked into leaves (cone or norm order)."""

    users: jnp.ndarray       # (m_pad, d) unit users, leaf order
    user_ids: jnp.ndarray    # (m_pad,) int32 original user row
    user_mask: jnp.ndarray   # (m_pad,) real (non-duplicate) users
    center: jnp.ndarray      # (n_blocks, d)
    omega: jnp.ndarray       # (n_blocks,)
    theta: jnp.ndarray       # (m_pad,)


def build_keys(key: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(k_idx, k_cone): the per-stage keys every build path must derive
    identically -- part of the fingerprint-stability contract."""
    k_idx, k_cone = jax.random.split(jax.random.fold_in(key, 0))
    return k_idx, k_cone


def split_items_by_norm(items: jnp.ndarray, n_top: int) -> NormSplit:
    """Stage 1: descending-norm sort + top-``n_top`` split (P' vs rest)."""
    norms = jnp.linalg.norm(items, axis=-1)
    order = jnp.argsort(-norms)
    items_sorted = items[order]
    return NormSplit(order=order,
                     top_items=items_sorted[:n_top],
                     top_ids=order[:n_top].astype(jnp.int32),
                     top_norms=norms[order][:n_top],
                     rest=items_sorted[n_top:])


def shift_item_ids(alsh: _alsh.SAALSHIndex, order: jnp.ndarray,
                   n_top: int) -> _alsh.SAALSHIndex:
    """Stage 2 epilogue: alsh.item_ids index ``rest``; shift them back to
    original item rows (padding stays -1)."""
    return alsh._replace(item_ids=jnp.where(
        alsh.item_ids >= 0,
        jnp.take(order.astype(jnp.int32),
                 jnp.clip(alsh.item_ids, 0, None) + n_top),
        -1))


def block_users(users: jnp.ndarray, key: jax.Array, *, leaf_size: int = 32,
                blocking: str = "cone") -> UserBlocking:
    """Stage 3: unit-normalize users and block them (cone tree or
    Simpfer-style contiguous "norm" chunks)."""
    unorm = jnp.linalg.norm(users, axis=-1, keepdims=True)
    users_unit = users / jnp.maximum(unorm, 1e-12)

    if blocking == "cone":
        blocks, padded, mask = _cone.build_cone_blocks(users_unit, key,
                                                       leaf_size)
    elif blocking == "norm":
        blocks, padded, mask = _cone.norm_blocks(users_unit, leaf_size)
    else:
        raise ValueError(f"unknown blocking {blocking!r}")

    perm = blocks.perm
    m = users.shape[0]
    return UserBlocking(users=padded[perm],
                        user_ids=(perm % m).astype(jnp.int32),
                        user_mask=mask[perm],
                        center=blocks.center, omega=blocks.omega,
                        theta=blocks.theta)


def lower_bounds(users_leaf: jnp.ndarray, user_mask: jnp.ndarray,
                 top_items: jnp.ndarray, k_max: int, n_blocks: int, *,
                 lb_rows=None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Stage 4: Simpfer per-user and per-block lower bounds over P'.

    lb_rows(users, top_items, k_max) -> (m, k_max) overrides the
    lower-bound computation; the staged pipeline passes a user-sharded
    version of ``simpfer.user_lower_bounds_impl`` here (each row is
    independent, so any row slicing is bitwise equal)."""
    lb_fn = lb_rows or _simpfer.user_lower_bounds
    lb = lb_fn(users_leaf, top_items, k_max)
    block_lb = _simpfer.block_lower_bounds(
        jnp.where(user_mask[:, None], lb, jnp.inf), n_blocks)
    # All-padding blocks (impossible with cyclic padding, but be safe):
    block_lb = jnp.where(jnp.isfinite(block_lb), block_lb, -jnp.inf)
    return lb, block_lb


def build(items: jnp.ndarray, users: jnp.ndarray, key: jax.Array, *,
          k_max: int = 50, n_top: int | None = None, leaf_size: int = 32,
          b: float = 0.5, n_bits: int = 128, tile: int = 512,
          max_partitions: int = 64, transform: str = "sat",
          blocking: str = "cone") -> SAHIndex:
    """Build the SAH index (Algorithm 4). items (n,d), users (m,d).

    Single-device composition of the build stages; engine/build.py runs
    the same stages with timing and optional mesh sharding.
    """
    if n_top is None:
        n_top = 2 * k_max
    k_idx, k_cone = build_keys(key)

    split = split_items_by_norm(items, n_top)
    alsh = _alsh.build_index(split.rest, k_idx, b=b, n_bits=n_bits,
                             tile=tile, max_partitions=max_partitions,
                             transform=transform)
    alsh = shift_item_ids(alsh, split.order, n_top)

    ub = block_users(users, k_cone, leaf_size=leaf_size, blocking=blocking)

    lb, block_lb = lower_bounds(ub.users, ub.user_mask, split.top_items,
                                k_max, ub.center.shape[0])

    return SAHIndex(alsh=alsh, users=ub.users, user_ids=ub.user_ids,
                    user_mask=ub.user_mask, center=ub.center, omega=ub.omega,
                    theta=ub.theta, user_lb=lb, block_lb=block_lb,
                    top_norms=split.top_norms, top_items=split.top_items,
                    top_ids=split.top_ids)


class QueryStats(NamedTuple):
    """Per-query pruning counters: scalars from ``rkmips``, (nq,) rows from
    the batch drivers. The first five are exact and layout-independent
    (bitwise equal across per-query / batched / sharded execution);
    tiles_scanned and chunks are diagnostics of how the work happened to be
    chunked — in the batched driver a mixed-query chunk's tile visits are
    charged to every query with an active lane in it (DESIGN.md SS9)."""

    blocks_alive: jnp.ndarray    # after Lemma 2
    users_alive: jnp.ndarray     # after Lemma 3
    n_no_lb: jnp.ndarray         # decided no by tau < L[k-1]
    n_yes_norm: jnp.ndarray      # decided yes by tau >= ||p_k||
    n_scan: jnp.ndarray          # users that needed the item scan
    tiles_scanned: jnp.ndarray   # total tile-visits across chunks
    chunks: jnp.ndarray
    truncated: jnp.ndarray       # 1 iff a scan budget skipped lanes


def _plan_one(index: SAHIndex, q: jnp.ndarray, k: int, tie_eps: float,
              delta_ip: jnp.ndarray | None = None,
              delta_mask: jnp.ndarray | None = None,
              delta_screen=None):
    """Lemmas 2-3 + dense tau + the O(1) decisions for ONE query.

    Shared verbatim by the per-query reference driver (``rkmips_impl``) and
    the batched planner (``rkmips_plan_impl`` lax.maps it), which is what
    makes the two paths bitwise equal: every dense product is the same
    matvec, every bound the same elementwise expression.

    delta_ip (m_pad, cap) / delta_mask (cap,) carry a staged-insert delta
    buffer (engine/artifact.py): live staged rows are exactly counted into
    every lane's initial count with the same strict ``> tau + eps`` rule as
    the main scan. ``delta_ip`` is query-independent (<u, p> only), so the
    callers compute it once per dispatch, outside any per-query map. The
    caller must hand an index view whose ``top_norms`` covers the staged
    rows (the "yes by norm" shortcut would otherwise fire against a stale,
    too-small k-th norm).

    delta_screen (delta_items, qips, qerr) replaces the exact delta_ip with
    the int8 screen (``sa_alsh.delta_screen_tables``): lanes whose
    quantized inner product clears the threshold by more than the sound
    error radius count without any f32 work, lanes that miss it by more
    than the radius are skipped, and only the thin in-band remainder falls
    back to the exact GEMM — the identical ``users @ delta_items.T``
    expression, under a ``lax.cond`` so the zero-band case pays nothing.
    Counts (hence predictions) stay bitwise equal to the f32 path; only
    who computes them changes (the SS13 over-admission argument, applied
    to the strict-count comparison instead of a top-k band).

    Returns (tau, count0, pred0, undecided, eps, block_alive, user_alive,
    no_lb, yes_norm), all in cone-leaf order.
    """
    m_pad = index.n_users
    leaf = m_pad // index.n_blocks
    qn = jnp.linalg.norm(q)
    eps = tie_eps * qn
    # f32 slack: the cone bounds go through arccos/cos roundtrips whose
    # relative error is ~1e-4; without slack a mathematically-tight bound
    # can flip a pruning decision (caught by the property tests).
    slack = 2e-4 * qn + eps

    # --- Lemma 2: block-level pruning -------------------------------------
    node_ub, phi = _cone.node_upper_bound(q, _cone.ConeBlocks(
        perm=jnp.arange(m_pad, dtype=jnp.int32), center=index.center,
        omega=index.omega, theta=index.theta))
    block_alive = node_ub >= index.block_lb[:, k - 1] - slack
    # --- Lemma 3: vector-level pruning ------------------------------------
    phi_u = jnp.repeat(phi, leaf)
    vec_ub = qn * jnp.cos(jnp.abs(phi_u - index.theta))
    user_alive = (index.user_mask & jnp.repeat(block_alive, leaf)
                  & (vec_ub >= index.user_lb[:, k - 1] - slack))

    # --- exact tau + O(1) decisions ---------------------------------------
    tau = index.users @ q
    no_lb = index.user_lb[:, k - 1] > tau + eps
    yes_norm = tau >= index.top_norms[k - 1]
    undecided = user_alive & ~no_lb & ~yes_norm
    count0 = _simpfer.init_count(index.user_lb, tau + eps)
    if delta_screen is not None:
        d_items, qips, qerr = delta_screen
        thr = (tau + eps)[:, None]
        live = delta_mask[None, :]
        sure = live & (qips - qerr > thr)
        band = live & ~sure & (qips + qerr > thr)

        def exact_band():
            dip = index.users @ d_items.T
            return jnp.sum(band & (dip > thr), axis=-1).astype(jnp.int32)

        band_n = jax.lax.cond(
            jnp.any(band), exact_band,
            lambda: jnp.zeros((m_pad,), jnp.int32))
        count0 = count0 + jnp.sum(sure, axis=-1).astype(jnp.int32) + band_n
    elif delta_ip is not None:
        count0 = count0 + jnp.sum(
            delta_mask[None, :] & (delta_ip > (tau + eps)[:, None]),
            axis=-1).astype(jnp.int32)
    pred0 = yes_norm & index.user_mask
    return (tau, count0, pred0, undecided, eps, block_alive, user_alive,
            no_lb, yes_norm)


def rkmips_impl(index: SAHIndex, q: jnp.ndarray, k: int, *, n_cand: int = 64,
                scan: str = "sketch", chunk: int = 256,
                tie_eps: float = 0.0, scan_precision: str = "f32",
                delta_items: jnp.ndarray | None = None,
                delta_mask: jnp.ndarray | None = None,
                delta_qitems: jnp.ndarray | None = None,
                delta_qscale: jnp.ndarray | None = None):
    """Algorithm 5 for one query, undecorated: the per-query REFERENCE
    driver. Returns (pred (m_pad,), QueryStats).

    pred is in cone-leaf order; use predictions_to_original() to map back.
    tie_eps: relative tie tolerance, must match the oracle (core/exact.py).
    delta_items (cap, d) / delta_mask (cap,): optional staged-insert buffer
    counted exactly into every lane (see ``_plan_one``; the engine's
    artifact lifecycle is the caller). delta_qitems/delta_qscale: the
    buffer's persisted int8 twin — consumed (as the SS13 screen) only when
    ``scan_precision == "int8"``, ignored otherwise, and never changes the
    counts either way. Call ``rkmips`` (the jitted alias)
    directly. Production batches go through the plan/execute pipeline
    (``rkmips_batch``), which is bitwise equal to this driver query for
    query; this one survives as the oracle the batched path's equivalence
    tests compare against.
    """
    m_pad = index.n_users
    chunk = min(chunk, m_pad)
    if scan_precision != "int8":
        delta_qitems = delta_qscale = None
    delta_ip = None
    delta_screen = None
    if delta_items is not None and delta_qitems is not None:
        qips, qerr = _alsh.delta_screen_tables(index.users, delta_qitems,
                                               delta_qscale)
        delta_screen = (delta_items, qips, qerr)
    elif delta_items is not None:
        delta_ip = index.users @ delta_items.T
    (tau, count0, pred0, undecided, eps, block_alive, user_alive,
     no_lb, yes_norm) = _plan_one(index, q, k, tie_eps, delta_ip,
                                  delta_mask, delta_screen)

    # --- compact survivors (cone order preserved) and scan in chunks ------
    und_ids = jnp.argsort(~undecided)                     # undecided first
    n_und = jnp.sum(undecided)

    def cond(state):
        ci, _, _ = state
        return (ci * chunk) < n_und

    def body(state):
        ci, pred, tiles = state
        # Clamp the slice start exactly as dynamic_slice would, so `active`
        # flags the lanes actually fetched: an unclamped position mask
        # would silently skip the tail lanes of an almost-all-undecided
        # queue whose length is not a chunk multiple (the final slice
        # re-covers a few already-decided lanes instead — idempotent).
        start = jnp.minimum(ci * chunk, m_pad - chunk)
        ids = jax.lax.dynamic_slice(und_ids, (start,), (chunk,))
        active = (start + jnp.arange(chunk)) < n_und
        users_c = jnp.take(index.users, ids, axis=0)
        taus_c = jnp.take(tau, ids)
        counts_c = jnp.take(count0, ids)
        is_yes, t_vis = _alsh.decide_count_impl(
            index.alsh, users_c, taus_c, counts_c, active, k,
            n_cand=n_cand, scan=scan, eps=eps,
            scan_precision=scan_precision)
        pred = pred.at[ids].set(jnp.where(active, is_yes, pred[ids]))
        return ci + 1, pred, tiles + t_vis

    n_chunks, pred, tiles = jax.lax.while_loop(
        cond, body, (jnp.asarray(0, jnp.int32), pred0,
                     jnp.asarray(0, jnp.int32)))

    stats = QueryStats(
        blocks_alive=jnp.sum(block_alive),
        users_alive=jnp.sum(user_alive),
        n_no_lb=jnp.sum(no_lb & index.user_mask),
        n_yes_norm=jnp.sum(yes_norm & index.user_mask),
        n_scan=n_und,
        tiles_scanned=tiles,
        chunks=n_chunks,
        truncated=jnp.asarray(0, jnp.int32),
    )
    return pred, stats


rkmips = functools.partial(
    jax.jit, static_argnames=("k", "n_cand", "scan", "chunk", "tie_eps",
                              "scan_precision"),
)(rkmips_impl)


class RkMIPSPlan(NamedTuple):
    """Phase-1 output of the batched plan/execute pipeline (DESIGN.md SS9).

    Everything phase 2 needs to drive the flat work queue, plus the
    per-query pruning counters (already final at plan time -- the execute
    phase only adds the tile/chunk diagnostics).

    Attributes:
      tau:     (nq, m_pad) f32 dense <u, q>.
      count0:  (nq, m_pad) int32 items already known to beat tau (P').
      pred0:   (nq, m_pad) bool O(1) "yes" decisions (tau >= ||p_k||).
      queue:   (nq * m_pad,) int32 flat (query, user) ids into the
               row-major (nq, m_pad) grid, undecided lanes first --
               query-major, cone-leaf order preserved within each query
               (the stable compaction sort keeps chunk locality).
      n_work:  () int32 number of undecided lanes (queue[:n_work] is work).
      eps:     (nq,) f32 per-query absolute tie tolerance.
      blocks_alive / users_alive / n_no_lb / n_yes_norm / n_scan:
               (nq,) int32 per-query pruning counters (QueryStats fields).
    """

    tau: jnp.ndarray
    count0: jnp.ndarray
    pred0: jnp.ndarray
    queue: jnp.ndarray
    n_work: jnp.ndarray
    eps: jnp.ndarray
    blocks_alive: jnp.ndarray
    users_alive: jnp.ndarray
    n_no_lb: jnp.ndarray
    n_yes_norm: jnp.ndarray
    n_scan: jnp.ndarray


def rkmips_plan_impl(index: SAHIndex, queries: jnp.ndarray, k: int, *,
                     tie_eps: float = 0.0,
                     delta_items: jnp.ndarray | None = None,
                     delta_mask: jnp.ndarray | None = None,
                     delta_qitems: jnp.ndarray | None = None,
                     delta_qscale: jnp.ndarray | None = None) -> RkMIPSPlan:
    """Phase 1 (plan): Lemmas 2-3, dense tau, O(1) decisions for the whole
    (nq, m_pad) grid, then compaction into one flat cross-query work queue.

    The per-query dense math runs under ``lax.map`` of the same
    ``_plan_one`` body the reference driver uses: one trace regardless of
    nq, and each query's floats are the *identical* matvec/bound ops --
    which is what keeps the batched path bitwise equal to the per-query
    oracle (a (nq, m) GEMM would round differently than nq matvecs).
    The queue stores flat int32 ids, so a batch is limited to
    nq * m_pad < 2**31 lanes (checked: both are static shapes).

    delta_items/delta_mask: optional staged-insert buffer; its (m_pad, cap)
    inner products are query-independent, so they are computed ONCE here —
    outside the per-query lax.map — and every query's plan reads the same
    values the per-query reference driver computes (bitwise).

    delta_qitems/delta_qscale: the buffer's persisted int8 twin. When
    present, the query-independent screen tables
    (``sa_alsh.delta_screen_tables``) replace the exact delta GEMM, and
    each query's plan falls back to f32 only for its in-band lanes (see
    ``_plan_one``) — counts stay bitwise equal. The batch driver forwards
    them only under ``scan_precision == "int8"``.
    """
    if queries.shape[0] * index.n_users >= 2 ** 31:
        raise ValueError(
            f"batch too large for the int32 flat work queue: nq * m_pad = "
            f"{queries.shape[0]} * {index.n_users} >= 2**31; split the "
            f"query batch")
    delta_ip = None
    delta_screen = None
    if delta_items is not None and delta_qitems is not None:
        qips, qerr = _alsh.delta_screen_tables(index.users, delta_qitems,
                                               delta_qscale)
        delta_screen = (delta_items, qips, qerr)
    elif delta_items is not None:
        delta_ip = index.users @ delta_items.T

    def one(q):
        (tau, count0, pred0, undecided, eps, block_alive, user_alive,
         no_lb, yes_norm) = _plan_one(index, q, k, tie_eps, delta_ip,
                                      delta_mask, delta_screen)
        return (tau, count0, pred0, undecided, eps,
                jnp.sum(block_alive), jnp.sum(user_alive),
                jnp.sum(no_lb & index.user_mask),
                jnp.sum(yes_norm & index.user_mask),
                jnp.sum(undecided))

    (tau, count0, pred0, undecided, eps, blocks_alive, users_alive,
     n_no_lb, n_yes_norm, n_scan) = jax.lax.map(one, queries)

    # Stable flat compaction: undecided lanes first, original (query-major,
    # cone-leaf) order preserved among them.
    queue = jnp.argsort(~undecided.reshape(-1)).astype(jnp.int32)
    n_work = jnp.sum(undecided)
    return RkMIPSPlan(tau=tau, count0=count0, pred0=pred0, queue=queue,
                      n_work=n_work, eps=eps, blocks_alive=blocks_alive,
                      users_alive=users_alive, n_no_lb=n_no_lb,
                      n_yes_norm=n_yes_norm, n_scan=n_scan)


rkmips_plan = functools.partial(
    jax.jit, static_argnames=("k", "tie_eps"))(rkmips_plan_impl)


def rkmips_execute_impl(index: SAHIndex, plan: RkMIPSPlan, k: int, *,
                        n_cand: int = 64, scan: str = "sketch",
                        chunk: int = 256, scan_precision: str = "f32",
                        scan_budget=0):
    """Phase 2 (execute): ONE while_loop over fixed-size, possibly
    mixed-query chunks of the flat work queue. Returns
    (pred (nq, m_pad) bool, QueryStats with (nq,) counters).

    Each lane looks up its own user row, tau, init count and per-query eps
    (lane i of the queue belongs to query ``queue[i] // m_pad``), so
    ``decide_count`` needs no per-chunk query context and lanes from a
    fast query never idle next to a slow query's lanes. Lane decisions are
    chunk-composition-independent, so predictions are bitwise equal to the
    per-query driver however the queue happens to be packed.

    Per-query ``tiles_scanned`` / ``chunks`` are recovered by segment
    accumulation keyed on each lane's query id: a chunk's tile count is
    charged to every query with an active lane in it. For nq == 1 this
    reproduces the per-query driver's numbers exactly; for mixed-query
    chunks they are packing diagnostics (tile visits are shared by
    co-resident lanes), unlike the plan-time counters, which are exact.

    ``scan_budget`` (a TRACED int32 scalar — different budget values share
    one executable) is the execution-only per-query cap that bounds
    adversarial queries (DESIGN.md SS15): once a query's charged
    tile-visits reach the budget, its remaining lanes are masked out of
    every later chunk — they keep their conservative plan-time decision
    (``pred0``, i.e. "not in the audience") and the query's ``truncated``
    stat is set, never silently wrong. The check runs between chunks, so a
    query may overshoot its budget by at most one chunk's tile walk; lanes
    already decided stay decided, and co-batched queries that are still
    under budget keep scanning (one pathological query can no longer force
    the deep tile walks of every chunk it rides in). ``scan_budget <= 0``
    disables the cap: that path is bitwise identical to the pre-budget
    pipeline, and any query the budget never bites keeps bitwise-identical
    predictions under either setting.
    """
    nq, m_pad = plan.tau.shape
    chunk = min(chunk, nq * m_pad)
    tau_f = plan.tau.reshape(-1)
    count_f = plan.count0.reshape(-1)
    budget = jnp.asarray(scan_budget, jnp.int32)

    def cond(state):
        ci, _, _, _, _ = state
        return (ci * chunk) < plan.n_work

    def body(state):
        ci, pred, tiles_q, chunks_q, trunc_q = state
        # Clamped start, for the same almost-full-queue tail case as the
        # per-query driver (see rkmips_impl).
        start = jnp.minimum(ci * chunk, nq * m_pad - chunk)
        ids = jax.lax.dynamic_slice(plan.queue, (start,), (chunk,))
        in_work = (start + jnp.arange(chunk)) < plan.n_work
        qid = ids // m_pad
        # Budget gate: lanes of an exhausted query leave the chunk before
        # the scan, so they stop forcing tile depth on their neighbours.
        over = (budget > 0) & (jnp.take(tiles_q, qid) >= budget)
        active = in_work & ~over
        users_c = jnp.take(index.users, ids % m_pad, axis=0)
        taus_c = jnp.take(tau_f, ids)
        counts_c = jnp.take(count_f, ids)
        eps_c = jnp.take(plan.eps, qid)
        is_yes, t_vis = _alsh.decide_count_impl(
            index.alsh, users_c, taus_c, counts_c, active, k,
            n_cand=n_cand, scan=scan, eps=eps_c,
            scan_precision=scan_precision)
        pred = pred.at[ids].set(jnp.where(active, is_yes, pred[ids]))
        present = jnp.zeros((nq,), bool).at[qid].max(active)
        tiles_q = tiles_q + jnp.where(present, t_vis, 0)
        chunks_q = chunks_q + present.astype(jnp.int32)
        trunc_q = trunc_q.at[qid].max(in_work & over)
        return ci + 1, pred, tiles_q, chunks_q, trunc_q

    zeros_q = jnp.zeros((nq,), jnp.int32)
    _, pred, tiles_q, chunks_q, trunc_q = jax.lax.while_loop(
        cond, body, (jnp.asarray(0, jnp.int32), plan.pred0.reshape(-1),
                     zeros_q, zeros_q, jnp.zeros((nq,), bool)))

    stats = QueryStats(
        blocks_alive=plan.blocks_alive,
        users_alive=plan.users_alive,
        n_no_lb=plan.n_no_lb,
        n_yes_norm=plan.n_yes_norm,
        n_scan=plan.n_scan,
        tiles_scanned=tiles_q,
        chunks=chunks_q,
        truncated=trunc_q.astype(jnp.int32),
    )
    return pred.reshape(nq, m_pad), stats


rkmips_execute = functools.partial(
    jax.jit, static_argnames=("k", "n_cand", "scan", "chunk",
                              "scan_precision"),
)(rkmips_execute_impl)


def rkmips_batch_impl(index: SAHIndex, queries: jnp.ndarray, k: int, *,
                      n_cand: int = 64, scan: str = "sketch",
                      chunk: int = 256, tie_eps: float = 0.0,
                      scan_precision: str = "f32",
                      delta_items: jnp.ndarray | None = None,
                      delta_mask: jnp.ndarray | None = None,
                      delta_qitems: jnp.ndarray | None = None,
                      delta_qscale: jnp.ndarray | None = None,
                      scan_budget=0):
    """Batched Algorithm 5, undecorated: plan + execute (DESIGN.md SS9).

    (nq, d) queries -> (pred (nq, m_pad), QueryStats with (nq,) counters).
    Bitwise equal to stacking per-query ``rkmips`` calls (predictions and
    the plan-time counters; tiles/chunks are packing diagnostics). An
    optional staged-insert delta buffer (delta_items/delta_mask, see
    ``_plan_one``) threads through the plan; its static capacity keeps the
    trace count flat however often the corpus churns, and under
    ``scan_precision == "int8"`` its persisted quantized twin
    (delta_qitems/delta_qscale) turns the delta counting into the SS13
    screen (bitwise-equal counts, f32 only for in-band lanes).
    ``scan_budget`` is the traced execution-only per-query tile cap (see
    ``rkmips_execute_impl``; 0 = uncapped). Call ``rkmips_batch``
    (the jitted alias) directly; the impl exists so
    ``repro.engine.sharding`` can trace the raw body under ``shard_map`` --
    one flat while_loop, no nested jit and no scan-of-while, which is what
    retires the jax 0.4.x per-query unroll workaround (the plan's lax.map
    contains only dense per-query math and is shard_map-safe).
    """
    if scan_precision != "int8":
        delta_qitems = delta_qscale = None
    plan = rkmips_plan_impl(index, queries, k, tie_eps=tie_eps,
                            delta_items=delta_items, delta_mask=delta_mask,
                            delta_qitems=delta_qitems,
                            delta_qscale=delta_qscale)
    return rkmips_execute_impl(index, plan, k, n_cand=n_cand, scan=scan,
                               chunk=chunk, scan_precision=scan_precision,
                               scan_budget=scan_budget)


@functools.partial(
    jax.jit, static_argnames=("k", "n_cand", "scan", "chunk", "tie_eps",
                              "scan_precision"))
def rkmips_batch(index: SAHIndex, queries: jnp.ndarray, k: int, *,
                 n_cand: int = 64, scan: str = "sketch", chunk: int = 256,
                 tie_eps: float = 0.0, scan_precision: str = "f32",
                 delta_items: jnp.ndarray | None = None,
                 delta_mask: jnp.ndarray | None = None,
                 delta_qitems: jnp.ndarray | None = None,
                 delta_qscale: jnp.ndarray | None = None,
                 scan_budget=0):
    """Jitted batched Algorithm 5 — see ``rkmips_batch_impl``. (A wrapper
    rather than a jit alias so the impl binds late: the compile-count tests
    wrap it to prove one body invocation per trace. ``scan_budget`` is
    deliberately traced, not static: per-tenant budgets share one
    executable.)"""
    return rkmips_batch_impl(index, queries, k, n_cand=n_cand, scan=scan,
                             chunk=chunk, tie_eps=tie_eps,
                             scan_precision=scan_precision,
                             delta_items=delta_items, delta_mask=delta_mask,
                             delta_qitems=delta_qitems,
                             delta_qscale=delta_qscale,
                             scan_budget=scan_budget)


def rkmips_batch_mapped(index: SAHIndex, queries: jnp.ndarray, k: int, *,
                        n_cand: int = 64, scan: str = "sketch",
                        chunk: int = 256, tie_eps: float = 0.0,
                        scan_precision: str = "f32",
                        delta_items: jnp.ndarray | None = None,
                        delta_mask: jnp.ndarray | None = None,
                        delta_qitems: jnp.ndarray | None = None,
                        delta_qscale: jnp.ndarray | None = None):
    """The legacy batch driver: ``lax.map`` of independent per-query
    ``rkmips`` while-loops. Superseded by the flat-queue ``rkmips_batch``
    (a fast query's lanes no longer pad out their own chunk grid while a
    slow query scans); retained as the second reference for equivalence
    tests and as the baseline ``benchmarks/bench_rkmips.py`` reports
    batched-vs-mapped wall time against. Always unbudgeted (it is the
    oracle the budget's conservative truncation is judged against)."""
    fn = functools.partial(rkmips, index, k=k, n_cand=n_cand, scan=scan,
                           chunk=chunk, tie_eps=tie_eps,
                           scan_precision=scan_precision,
                           delta_items=delta_items, delta_mask=delta_mask,
                           delta_qitems=delta_qitems,
                           delta_qscale=delta_qscale)
    return jax.lax.map(lambda q: fn(q), queries)


def predictions_to_original(index: SAHIndex, pred: jnp.ndarray,
                            n_users: int) -> jnp.ndarray:
    """Map leaf-order predictions (..., m_pad) back to original rows (..., m).

    Every padding convention in the stack (SS2 cyclic user padding; the
    sharding-time dead duplicate leaves of ``engine/sharding.py::pad_index``)
    must keep this mapping exact: padded rows are masked (``user_mask`` is
    False) so they can never set an original row, and the scatter drops any
    id outside [0, n_users) outright — a phantom id (e.g. a -1 sentinel)
    cannot silently clamp onto a real user.
    """
    masked = (pred & index.user_mask).astype(jnp.int32)
    out = jnp.zeros(pred.shape[:-1] + (n_users,), jnp.int32)
    out = out.at[..., index.user_ids].max(masked, mode="drop")
    return out > 0
