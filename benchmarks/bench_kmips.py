"""Fig. 6: SA-ALSH vs H2-ALSH for standalone kMIPS (recall + query time) and
Table 2: F1 of answering RkMIPS with plain kMIPS results (they are different
problems -- the paper's motivation table).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import exact, metrics, sa_alsh


def run(n=16384, m=16384, d=64, nq=32, ks=(1, 5, 10, 20, 30, 40, 50)):
    wl = common.make_workload("nmf", n, m, d, nq, ks=(1, 10, 50))
    rows = []
    tv, ti = exact.kmips(wl.items, wl.queries, max(ks))

    for transform in ("sat", "qnf"):
        name = "SA-ALSH" if transform == "sat" else "H2-ALSH"
        key = jax.random.PRNGKey(2)
        t0 = time.perf_counter()
        idx = sa_alsh.build_index(wl.items, key, transform=transform)
        jax.block_until_ready(idx.codes)
        rows.append(common.fmt_row(f"fig6/index/{name}",
                                   (time.perf_counter() - t0) * 1e6, ""))
        for k in ks:
            n_cand = max(64, 4 * k)       # candidate depth scales with k
            vals, ids, _ = sa_alsh.kmips_topk(idx, wl.queries, k,
                                              n_cand=n_cand)
            jax.block_until_ready(vals)
            t0 = time.perf_counter()
            vals, ids, tiles = sa_alsh.kmips_topk(idx, wl.queries, k,
                                                  n_cand=n_cand)
            jax.block_until_ready(vals)
            dt = (time.perf_counter() - t0) / nq
            rec = float(jnp.mean(metrics.recall_at_k(ids, ti[:, :k])))
            rows.append(common.fmt_row(
                f"fig6/kmips/{name}/k={k}", dt * 1e6,
                f"recall={rec:.3f};tiles={int(tiles)}"))

    # Table 2: use top-k users by <u, q> as a (bad) RkMIPS answer.
    for k in (1, 10, 50):
        scores = wl.queries @ wl.users_unit.T            # (nq, m)
        _, topu = jax.lax.top_k(scores, k)
        pred = jnp.zeros(scores.shape, bool)
        pred = jax.vmap(lambda p, i: p.at[i].set(True))(pred, topu)
        f1 = float(jnp.mean(metrics.f1_score(pred, wl.truth[k])))
        rows.append(common.fmt_row(f"table2/kmips_as_rkmips/k={k}", 0.0,
                                   f"f1={f1:.3f}"))
    return rows
