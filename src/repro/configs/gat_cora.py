"""gat-cora: 2L d_hidden=8 8 heads attention aggregator. [arXiv:1710.10903]

Shapes span the three GNN regimes: full-batch small (Cora), neighbor-sampled
training (Reddit-scale fanout 15-10), full-batch large (ogbn-products), and
batched small graphs (molecule). Edge counts are padded to 8192-multiples for
even sharding over the 256/512-way mesh.
"""

import jax.numpy as jnp

from repro.configs import base
from repro.models.gat import GATConfig


def _pad(x: int, mult: int = 8192) -> int:
    return -(-x // mult) * mult


SHAPES = (
    base.ShapeSpec("full_graph_sm", "train",
                   {"n_nodes": 2708, "n_edges": _pad(10556), "d_feat": 1433,
                    "n_classes": 7}),
    base.ShapeSpec("minibatch_lg", "train",
                   {"n_nodes": 169984, "n_edges": _pad(168960), "d_feat": 602,
                    "n_classes": 41, "batch_nodes": 1024,
                    "fanout": (15, 10)},
                   note="padded 2-hop sampled subgraph: 1024 seeds x "
                        "(1 + 15 + 150) nodes; host CSR sampler feeds it"),
    base.ShapeSpec("ogb_products", "train",
                   {"n_nodes": 2449029, "n_edges": _pad(61859140),
                    "d_feat": 100, "n_classes": 47}),
    base.ShapeSpec("molecule", "train",
                   {"n_nodes": 30 * 128, "n_edges": _pad(64 * 128, 1024),
                    "d_feat": 32, "n_classes": 2, "n_graphs": 128},
                   note="block-diagonal batch of 128 30-node graphs; "
                        "graph-level classification via segment mean-pool"),
)


def make_config() -> GATConfig:
    return GATConfig(name="gat-cora", n_layers=2, d_hidden=8, n_heads=8,
                     d_in=1433, n_classes=7)


def make_smoke_config() -> GATConfig:
    return GATConfig(name="gat-smoke", n_layers=2, d_hidden=4, n_heads=2,
                     d_in=16, n_classes=3)


base.register(base.ArchSpec(
    arch_id="gat-cora", family="gnn", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=SHAPES,
    source="arXiv:1710.10903",
    notes="SAH inapplicable (no inner-product search in message passing); "
          "d_in/n_classes are overridden per shape"))
