"""Pallas TPU kernel: fused inner-product scoring + per-tile top-k.

Hot path of `retrieval_cand` (one query against 10^6 candidates) and of the
exact re-ranking step inside SAH: scores = Q @ C^T immediately reduced to the
k best per candidate tile, so the (q, n) score matrix never reaches HBM --
only (q, n_tiles, k) survives (a n/(tiles*k) ~ 64x output-byte reduction at
tile=2048, k=32). A cheap jnp merge of the per-tile winners produces the
global top-k (done in ops.ip_topk).

Per-tile top-k is a k-step select loop (argmax + mask) on the VPU; the matmul
runs on the MXU. k is a compile-time constant (<= 128 in all our uses).

Tiling: grid (q_blocks, n_tiles); block (bq, d) x (bn, d) -> out (bq, 1, k).
VMEM at bq=128, bn=2048, d=256: inputs 128*256*4 + 2048*256*4 = 2.2 MB,
scores 128*2048*4 = 1 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ip_topk_kernel(q_ref, c_ref, vals_ref, ids_ref, *, k: int, block_n: int):
    j = pl.program_id(1)
    q = q_ref[...]                          # (bq, d)
    c = c_ref[...]                          # (bn, d)
    scores = jnp.dot(q, c.T, preferred_element_type=jnp.float32)  # (bq, bn)
    base = (j * block_n).astype(jnp.int32)

    def body(i, carry):
        s, vals, ids = carry
        arg = jnp.argmax(s, axis=-1)                       # (bq,)
        best = jnp.max(s, axis=-1)                         # (bq,)
        vals = vals.at[:, i].set(best)
        ids = ids.at[:, i].set(arg.astype(jnp.int32) + base)
        # Mask the selected column out for the next round.
        onehot = jax.nn.one_hot(arg, s.shape[-1], dtype=jnp.bool_)
        s = jnp.where(onehot, -jnp.inf, s)
        return s, vals, ids

    bq = scores.shape[0]
    vals0 = jnp.full((bq, k), -jnp.inf, jnp.float32)
    ids0 = jnp.zeros((bq, k), jnp.int32)
    _, vals, ids = jax.lax.fori_loop(0, k, body, (scores, vals0, ids0))
    vals_ref[...] = vals[:, None, :]
    ids_ref[...] = ids[:, None, :]


@functools.partial(jax.jit,
                   static_argnames=("k", "block_q", "block_n", "interpret"))
def ip_topk_tiles(queries: jnp.ndarray, items: jnp.ndarray, k: int,
                  *, block_q: int = 128, block_n: int = 2048,
                  interpret: bool = False
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tile top-k inner products.

    queries (q, d) f32, items (n, d) f32 -> (vals, ids) each (q, n_tiles, k);
    ids are global row indices into items. Requires q % block_q == 0,
    n % block_n == 0 and block_n >= k.
    """
    q, d = queries.shape
    n, d2 = items.shape
    assert d == d2, (d, d2)
    assert q % block_q == 0 and n % block_n == 0 and block_n >= k
    n_tiles = n // block_n
    kernel = functools.partial(_ip_topk_kernel, k=k, block_n=block_n)
    return pl.pallas_call(
        kernel,
        grid=(q // block_q, n_tiles),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, 1, k), lambda i, j: (i, j, 0)),
            pl.BlockSpec((block_q, 1, k), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, n_tiles, k), jnp.float32),
            jax.ShapeDtypeStruct((q, n_tiles, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, items)
