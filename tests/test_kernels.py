"""Pallas kernel correctness: interpret-mode vs jnp oracle over shape/dtype
sweeps (per-kernel allclose, exact equality for integer outputs).

Two execution modes are covered for each kernel: *interpret* (the Pallas
body run per grid step — what CPU CI exercises, ``ci.yml`` kernels job) and
*compiled* (the jitted dispatch path of ``kernels/ops.py``; on CPU that is
the jit-compiled lax mirror, on TPU the same calls hit the compiled Pallas
kernels). Hypothesis properties live at the bottom behind a soft import —
the hypothesis-free parametrized mirrors above them keep tier-1 coverage
on minimal installs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention as fa
from repro.kernels import fused_scan as fs
from repro.kernels import hamming_scan, ip_topk, ref, srp_hash
from repro.kernels import ops as kops
from repro.kernels.ops import _merge_topk

try:
    import hypothesis
    import hypothesis.extra.numpy as hnp  # noqa: F401  (kept for strategies)
    import hypothesis.strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False


def _codes(key, n, w):
    return jax.random.randint(key, (n, w), 0, 2**31 - 1,
                              dtype=jnp.int32).astype(jnp.uint32)


@pytest.mark.parametrize("q,n,w,bq,bn", [
    (64, 256, 4, 32, 128),
    (128, 512, 8, 128, 512),
    (32, 1024, 1, 32, 256),
    (256, 256, 16, 64, 64),
])
def test_hamming_matches_ref(q, n, w, bq, bn):
    k1, k2 = jax.random.split(jax.random.PRNGKey(q + n + w))
    qc, ic = _codes(k1, q, w), _codes(k2, n, w)
    out = hamming_scan.hamming_scores(qc, ic, block_q=bq, block_n=bn,
                                      interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.hamming_scores(qc, ic)))


def test_hamming_identity_and_complement():
    k = jax.random.PRNGKey(0)
    c = _codes(k, 64, 4)
    d = hamming_scan.hamming_scores(c, c, block_q=64, block_n=64,
                                    interpret=True)
    assert (np.diag(np.asarray(d)) == 0).all()
    comp = jnp.bitwise_xor(c, jnp.uint32(0xFFFFFFFF))
    d2 = hamming_scan.hamming_scores(c, comp, block_q=64, block_n=64,
                                     interpret=True)
    assert (np.diag(np.asarray(d2)) == 32 * 4).all()


@pytest.mark.parametrize("n,d,bits,bn", [
    (256, 64, 128, 128),
    (512, 101, 256, 256),
    (128, 17, 32, 64),
])
def test_srp_hash_matches_ref(n, d, bits, bn):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n + d))
    x = jax.random.normal(k1, (n, d))
    proj = jax.random.normal(k2, (d, bits))
    out = srp_hash.srp_hash(x, proj, block_n=min(bn, n), interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.srp_hash(x, proj)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_srp_hash_dtypes(dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    x = jax.random.normal(k1, (128, 32)).astype(dtype)
    proj = jax.random.normal(k2, (32, 64)).astype(dtype)
    out = srp_hash.srp_hash(x.astype(jnp.float32),
                            proj.astype(jnp.float32), block_n=128,
                            interpret=True)
    assert out.dtype == jnp.uint32


@pytest.mark.parametrize("q,n,d,k,bq,bn", [
    (8, 1024, 32, 8, 8, 256),
    (16, 2048, 64, 32, 16, 512),
    (4, 512, 128, 100, 4, 512),
])
def test_ip_topk_matches_ref(q, n, d, k, bq, bn):
    k1, k2 = jax.random.split(jax.random.PRNGKey(q * n))
    queries = jax.random.normal(k1, (q, d))
    items = jax.random.normal(k2, (n, d))
    vals, ids = ip_topk.ip_topk_tiles(queries, items, k, block_q=bq,
                                      block_n=bn, interpret=True)
    bv, bi = _merge_topk(vals, ids, k)
    rv, ri = ref.ip_topk(queries, items, k)
    np.testing.assert_allclose(np.asarray(bv), np.asarray(rv), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(bi), np.asarray(ri))


@pytest.mark.parametrize("b,h,s,dh,bq,bk,causal", [
    (2, 3, 128, 32, 32, 32, True),
    (1, 2, 256, 64, 64, 128, True),
    (2, 2, 64, 16, 64, 16, False),
    (1, 1, 128, 128, 128, 32, True),
])
def test_flash_attention_matches_ref(b, h, s, dh, bq, bk, causal):
    key = jax.random.PRNGKey(b * s + dh)
    q = jax.random.normal(key, (b, h, s, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, h, s, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, h, s, dh))
    out = fa.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                             interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=5e-5)


def test_flash_attention_bf16():
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (1, 2, 64, 32)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (1, 2, 64, 32)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (1, 2, 64, 32)).astype(jnp.bfloat16)
    out = fa.flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention(q, k, v)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)


def test_ip_topk_with_duplicate_scores():
    # tie-breaking: top_k prefers lower index; the tiled kernel must agree
    queries = jnp.ones((4, 16))
    items = jnp.concatenate([jnp.ones((64, 16)), jnp.zeros((64, 16))])
    vals, ids = ip_topk.ip_topk_tiles(queries, items, 8, block_q=4,
                                      block_n=32, interpret=True)
    bv, bi = _merge_topk(vals, ids, 8)
    rv, ri = ref.ip_topk(queries, items, 8)
    np.testing.assert_allclose(np.asarray(bv), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(bi), np.asarray(ri))


# ---------------------------------------------------------------------------
# fused_scan (DESIGN.md SS13): Hamming filter + top-n_cand + dequantized IP.
# ---------------------------------------------------------------------------


def _fused_inputs(seed, c, t, w, d, live=0.8):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    ucodes = _codes(ks[0], c, w)
    icodes = _codes(ks[1], t, w)
    mask = jax.random.bernoulli(ks[2], live, (t,))
    qitems = jax.random.randint(ks[3], (t, d), -127, 128,
                                dtype=jnp.int32).astype(jnp.int8)
    qscale = jax.random.uniform(ks[4], (t,), minval=0.0, maxval=0.1)
    users = jax.random.normal(ks[5], (c, d))
    return ucodes, icodes, mask, qitems, qscale, users


# prime / non-power-of-2 candidate counts, tile sizes and dims throughout:
# nothing in the kernel may assume lane-width alignment.
_FUSED_SHAPES = [
    # (C, T, W, d, n_cand)
    (16, 97, 3, 19, 7),
    (8, 256, 4, 32, 16),
    (4, 513, 1, 5, 64),
    (32, 144, 8, 24, 13),
    (3, 31, 2, 17, 31),     # n_cand == T: every live row selected
]


@pytest.mark.parametrize("c,t,w,d,n_cand", _FUSED_SHAPES)
def test_fused_scan_lax_matches_ref(c, t, w, d, n_cand):
    # the lax mirror is the compiled CPU hot path: cand AND qips must be
    # bitwise the oracle's (same selection tie-breaks, same gather+einsum)
    args = _fused_inputs(c + t + d, c, t, w, d)
    rc, rq = ref.fused_scan(*args, n_cand)
    lc, lq = jax.jit(fs.fused_scan_lax, static_argnames=("n_cand",))(
        *args, n_cand=n_cand)
    np.testing.assert_array_equal(np.asarray(lc), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(lq), np.asarray(rq))


@pytest.mark.parametrize("c,t,w,d,n_cand,bq", [
    (16, 97, 3, 19, 7, 8),
    (8, 64, 4, 32, 16, 8),
    (6, 129, 2, 11, 5, 3),
    (5, 100, 1, 8, 10, 1),   # block_q=1: the tail-chunk fallback
])
def test_fused_scan_tiles_matches_ref(c, t, w, d, n_cand, bq):
    # interpret-mode Pallas: cand bitwise, qips allclose (the in-kernel
    # one-hot matmul gather reassociates the dot product; only the error
    # ball's slack, not bitwiseness, is contractual for qips here)
    args = _fused_inputs(c * t, c, t, w, d)
    rc, rq = ref.fused_scan(*args, n_cand)
    pc, pq = fs.fused_scan_tiles(*args, n_cand=n_cand, block_q=bq,
                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(pc), np.asarray(rc))
    np.testing.assert_allclose(np.asarray(pq), np.asarray(rq),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("impl", ["lax", "tiles"])
def test_fused_scan_all_masked_lanes(impl, monkeypatch):
    # a fully dead tile must still produce the oracle's deterministic
    # candidates (all distances +BIG -> lowest rows win) without NaNs
    args = list(_fused_inputs(11, 8, 53, 2, 9))
    args[2] = jnp.zeros((53,), bool)
    rc, rq = ref.fused_scan(*args, 6)
    if impl == "lax":
        oc, oq = fs.fused_scan_lax(*args, n_cand=6)
        np.testing.assert_array_equal(np.asarray(oq), np.asarray(rq))
    else:
        oc, oq = fs.fused_scan_tiles(*args, n_cand=6, block_q=4,
                                     interpret=True)
        assert np.isfinite(np.asarray(oq)).all()
    np.testing.assert_array_equal(np.asarray(oc), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(rc),
                                  np.tile(np.arange(6, dtype=np.int32),
                                          (8, 1)))


def test_fused_scan_masked_rows_never_selected():
    # with >= n_cand live rows, no masked row can appear among candidates
    args = list(_fused_inputs(23, 12, 64, 2, 7, live=0.5))
    mask = np.asarray(args[2])
    n_cand = 8
    assert mask.sum() >= n_cand
    for fn in (lambda: ref.fused_scan(*args, n_cand),
               lambda: fs.fused_scan_lax(*args, n_cand=n_cand),
               lambda: fs.fused_scan_tiles(*args, n_cand=n_cand,
                                           block_q=4, interpret=True)):
        cand, _ = fn()
        assert mask[np.asarray(cand)].all()


def test_fused_scan_ops_dispatch(monkeypatch):
    # the public entry point: compiled lax path by default on CPU (bitwise
    # equal to the oracle), interpret-mode Pallas under the env override --
    # C prime so the dispatch exercises its block_q=1 fallback
    args = _fused_inputs(5, 7, 96, 4, 16)
    rc, rq = ref.fused_scan(*args, 9)
    monkeypatch.delenv("REPRO_FORCE_INTERPRET", raising=False)
    lc, lq = kops.fused_scan(*args, n_cand=9)
    np.testing.assert_array_equal(np.asarray(lc), np.asarray(rc))
    np.testing.assert_array_equal(np.asarray(lq), np.asarray(rq))
    monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    pc, pq = kops.fused_scan(*args, n_cand=9)
    np.testing.assert_array_equal(np.asarray(pc), np.asarray(rc))
    np.testing.assert_allclose(np.asarray(pq), np.asarray(rq),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("c,n,d", [(7, 64, 128), (5, 24, 300)])
def test_band_einsum_bitwise_stable(c, n, d):
    """Pin the backend property core/sa_alsh.py::_tile_beat_int8 leans on:
    a gathered-subset ``einsum("cnd,cd->cn")`` with candidate-axis width
    S >= 8 is bitwise equal, element for element, to the full-width einsum
    the f32 scan computes. (Widths 1/2/4 are NOT stable on this backend —
    XLA picks a different reduction shape — which is why the band re-rank
    uses s_slots = min(16, n_cand), never fewer than 8.)"""
    ks = jax.random.split(jax.random.PRNGKey(c * d), 3)
    vecs = jax.random.normal(ks[0], (c, n, d))
    users = jax.random.normal(ks[1], (c, d))
    full = jnp.einsum("cnd,cd->cn", vecs, users)
    for s in (8, 16):
        pos = jnp.argsort(jax.random.uniform(ks[2], (c, n)), axis=-1)[:, :s]
        sub_vecs = jnp.take_along_axis(vecs, pos[..., None], axis=1)
        sub = jnp.einsum("cnd,cd->cn", sub_vecs, users)
        want = jnp.take_along_axis(full, pos, axis=-1)
        np.testing.assert_array_equal(np.asarray(sub), np.asarray(want))


def test_hamming_and_ip_topk_dispatch_tail_shapes(monkeypatch):
    # prime (non-tile-multiple) shapes through the public dispatch in both
    # modes: the block-size fallbacks must keep results exactly the oracle's
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    qc, ic = _codes(k1, 13, 2), _codes(k2, 17, 2)
    queries = jax.random.normal(k3, (13, 29))
    items = jax.random.normal(jax.random.fold_in(k3, 1), (89, 29))
    for env in (None, "1"):
        if env is None:
            monkeypatch.delenv("REPRO_FORCE_INTERPRET", raising=False)
        else:
            monkeypatch.setenv("REPRO_FORCE_INTERPRET", env)
        np.testing.assert_array_equal(
            np.asarray(kops.hamming_scores(qc, ic)),
            np.asarray(ref.hamming_scores(qc, ic)))
        vals, ids = kops.ip_topk(queries, items, 11)
        rv, ri = ref.ip_topk(queries, items, 11)
        np.testing.assert_allclose(np.asarray(vals), np.asarray(rv),
                                   rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(ids), np.asarray(ri))


# ---------------------------------------------------------------------------
# Hypothesis properties (soft dependency; mirrors above keep tier-1 coverage).
# ---------------------------------------------------------------------------


if HAVE_HYP:
    hypothesis.settings.register_profile(
        "kernels", deadline=None, max_examples=25,
        suppress_health_check=[hypothesis.HealthCheck.too_slow,
                               hypothesis.HealthCheck.data_too_large])
    hypothesis.settings.load_profile("kernels")

    # Shapes + a PRNG seed are the drawn quantities; array contents come
    # from jax.random so example generation stays cheap and shrinkable.
    @hypothesis.given(st.integers(1, 12), st.integers(1, 80),
                      st.integers(1, 4), st.integers(1, 24),
                      st.integers(1, 16), st.integers(0, 2**16),
                      st.floats(0.0, 1.0))
    def test_fused_scan_property(c, t, w, d, n_cand, seed, live):
        hypothesis.assume(n_cand <= t)
        args = _fused_inputs(seed, c, t, w, d, live=live)
        rc, rq = ref.fused_scan(*args, n_cand)
        lc, lq = fs.fused_scan_lax(*args, n_cand=n_cand)
        np.testing.assert_array_equal(np.asarray(lc), np.asarray(rc))
        np.testing.assert_array_equal(np.asarray(lq), np.asarray(rq))
        pc, pq = fs.fused_scan_tiles(*args, n_cand=n_cand, block_q=1,
                                     interpret=True)
        np.testing.assert_array_equal(np.asarray(pc), np.asarray(rc))
        np.testing.assert_allclose(np.asarray(pq), np.asarray(rq),
                                   rtol=1e-5, atol=1e-5)

    @hypothesis.given(st.integers(1, 16), st.integers(1, 32),
                      st.integers(1, 8), st.integers(0, 2**16))
    def test_hamming_property(q, n, w, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        qc, ic = _codes(k1, q, w), _codes(k2, n, w)
        out = hamming_scan.hamming_scores(qc, ic, block_q=q, block_n=n,
                                          interpret=True)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(ref.hamming_scores(qc, ic)))

    @hypothesis.given(st.integers(1, 8), st.integers(1, 48),
                      st.integers(1, 24), st.integers(1, 48),
                      st.integers(0, 2**16))
    def test_ip_topk_property(q, n, d, k, seed):
        hypothesis.assume(k <= n)
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        queries = jax.random.normal(k1, (q, d))
        items = jax.random.normal(k2, (n, d))
        vals, ids = ip_topk.ip_topk_tiles(queries, items, k, block_q=q,
                                          block_n=n, interpret=True)
        bv, bi = _merge_topk(vals, ids, k)
        rv, ri = ref.ip_topk(queries, items, k)
        np.testing.assert_allclose(np.asarray(bv), np.asarray(rv),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(bi), np.asarray(ri))
