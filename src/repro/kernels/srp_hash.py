"""Pallas TPU kernel: fused SRP hashing -- projection matmul + sign + bitpack.

Computes uint32-packed SimHash codes for a batch of (already transformed)
vectors:  code[i, w] bit j = (x[i] . proj[:, 32w+j] >= 0).

Fusion rationale (memory roofline): the naive composition materializes the
(n, B) sign/projection matrix in HBM (n*B*4 bytes with f32 projections) before
packing. Fused, only the (n, B/32) uint32 codes leave the chip: a 128x
reduction in output bytes. The matmul itself runs on the MXU; sign+pack on the
VPU, all within one VMEM residency.

Tiling: grid over row blocks; each instance handles (block_n, d) x (d, B).
d (the vector dim, <= a few hundred here) and B (128-512 bits) are kept whole
per block: VMEM at block_n=256, d=512, B=256: in 256*512*4 = 512 KB,
proj 512*256*4 = 512 KB, scores 256*256*4 = 256 KB -- fine.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def _srp_kernel(x_ref, p_ref, out_ref):
    x = x_ref[...]                         # (bn, d) f32
    p = p_ref[...]                         # (d, B) f32
    scores = jnp.dot(x, p, preferred_element_type=jnp.float32)   # MXU
    signs = (scores >= 0.0).astype(jnp.uint32)                   # (bn, B)
    bn, b = signs.shape
    grouped = signs.reshape(bn, b // 32, 32)
    # 2^j weights built in-kernel (TPU needs >= 2D iota; constants cannot be
    # captured from the enclosing module).
    bit = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    pow2 = jnp.left_shift(jnp.uint32(1), bit)
    out_ref[...] = jnp.sum(grouped * pow2, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def srp_hash(x: jnp.ndarray, proj: jnp.ndarray, *, block_n: int = 256,
             interpret: bool = False) -> jnp.ndarray:
    """x (n, d) f32, proj (d, B) f32, B % 32 == 0 -> (n, B//32) uint32 codes."""
    n, d = x.shape
    d2, b = proj.shape
    assert d == d2 and b % 32 == 0, (d, d2, b)
    assert n % block_n == 0, (n, block_n)
    return pl.pallas_call(
        _srp_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, b // 32), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b // 32), jnp.uint32),
        interpret=interpret,
    )(x, proj)
