"""Attention (chunked vs naive, decode vs full) and MoE dispatch tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models.moe import MoEConfig


@pytest.mark.parametrize("sq,skv,chunk", [(16, 16, 4), (17, 17, 8),
                                          (8, 32, 16), (32, 32, 32)])
def test_chunked_matches_naive(sq, skv, chunk):
    key = jax.random.PRNGKey(sq * skv)
    q = jax.random.normal(key, (2, 3, sq, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 3, skv, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 3, skv, 8))
    a = attn.chunked_attention(q, k, v, chunk=chunk)
    b = attn.naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_decode_matches_naive_last_position():
    key = jax.random.PRNGKey(3)
    b, h, s, dh = 2, 4, 12, 16
    q = jax.random.normal(key, (b, h, dh))
    kc = jax.random.normal(jax.random.fold_in(key, 1), (b, h, 32, dh))
    vc = jax.random.normal(jax.random.fold_in(key, 2), (b, h, 32, dh))
    out = attn.decode_attention(q, kc, vc, jnp.asarray(s))
    ref = attn.naive_attention(q[:, :, None, :], kc[:, :, :s], vc[:, :, :s],
                               causal=False)[:, :, 0, :]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_repeat_kv():
    x = jnp.arange(2 * 2 * 3 * 4, dtype=jnp.float32).reshape(2, 2, 3, 4)
    r = attn.repeat_kv(x, 3)
    assert r.shape == (2, 6, 3, 4)
    np.testing.assert_array_equal(np.asarray(r[:, 0]), np.asarray(r[:, 1]))
    np.testing.assert_array_equal(np.asarray(r[:, 3]), np.asarray(x[:, 1]))


def test_moe_no_drop_equals_dense_expert_mix():
    """With capacity >= all tokens, MoE output == explicit dense gather."""
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16,
                    capacity_factor=100.0)
    key = jax.random.PRNGKey(0)
    params = moe_lib.init_moe_params(key, 8, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 6, 8))
    out, aux = moe_lib.moe_ffn(x, params, cfg, moe_lib.ShardingPolicy(
        mesh=None, rules={}))

    # reference: route every token through its top-k experts densely
    x2 = x.reshape(-1, 8)
    logits = x2 @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, 2)
    gates = top_p / top_p.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x2)
    for e in range(4):
        h = jax.nn.silu(x2 @ params["w_gate"][e]) * (x2 @ params["w_in"][e])
        y = h @ params["w_out"][e]
        w = jnp.where(top_e == e, gates, 0.0).sum(-1)
        ref = ref + y * w[:, None]
    np.testing.assert_allclose(np.asarray(out.reshape(-1, 8)),
                               np.asarray(ref), atol=1e-4)
    assert float(aux) > 0.0


def test_moe_capacity_drops_tokens():
    cfg = MoEConfig(n_experts=2, top_k=1, d_ff_expert=8,
                    capacity_factor=0.25)
    key = jax.random.PRNGKey(1)
    params = moe_lib.init_moe_params(key, 4, cfg)
    x = jax.random.normal(key, (1, 16, 4))
    out, _ = moe_lib.moe_ffn(x, params, cfg,
                             moe_lib.ShardingPolicy(mesh=None, rules={}))
    # over-capacity tokens produce zero expert output
    zero_rows = jnp.sum(jnp.all(out.reshape(-1, 4) == 0.0, axis=-1))
    assert int(zero_rows) >= 8        # capacity 2/expert * 2 experts kept


def test_dispatch_indices_unique_slots():
    ids = jnp.asarray([0, 1, 0, 1, 0, 2, 2, 1], jnp.int32)
    slot, keep = moe_lib._dispatch_indices(ids, 4, capacity=2)
    kept_slots = np.asarray(slot)[np.asarray(keep)]
    assert len(set(kept_slots.tolist())) == len(kept_slots)
    # per-expert kept counts respect capacity
    for e in range(4):
        assert int(((np.asarray(ids) == e) & np.asarray(keep)).sum()) <= 2
