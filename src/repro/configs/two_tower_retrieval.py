"""two-tower-retrieval: embed_dim=256 output, towers 1024-512-256, dot
interaction, sampled-softmax training. [Yi et al. RecSys'19]

This is the paper's home architecture: `retrieval_cand` (1 query x 1M
candidates) is MIPS -- served either exact (fused ip_topk kernel) or through
the SAH/SA-ALSH sketch index; the reverse direction is RkMIPS itself.
"""

from repro.configs import base
from repro.models.embedding import EmbeddingConfig
from repro.models.recsys import TwoTowerConfig


def make_config() -> TwoTowerConfig:
    return TwoTowerConfig(
        name="two-tower-retrieval",
        user_embedding=EmbeddingConfig(
            vocab_sizes=(10_000_000, 100_000, 10_000), dim=64),
        item_embedding=EmbeddingConfig(
            vocab_sizes=(10_000_000, 100_000), dim=64),
        tower_dims=(1024, 512), out_dim=256)


def make_smoke_config() -> TwoTowerConfig:
    return TwoTowerConfig(
        name="two-tower-smoke",
        user_embedding=EmbeddingConfig(vocab_sizes=(5000, 100), dim=16),
        item_embedding=EmbeddingConfig(vocab_sizes=(2000, 50), dim=16),
        tower_dims=(64, 32), out_dim=32)


base.register(base.ArchSpec(
    arch_id="two-tower-retrieval", family="recsys", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=base.RECSYS_SHAPES,
    source="RecSys'19 (YouTube)",
    notes="paper-technique cell: retrieval_cand has exact + SAH serve modes"))
