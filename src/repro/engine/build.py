"""Staged, mesh-parallel SAH index build (DESIGN.md SS11).

``core/sah.py::build`` is a composition of four pure stages (Algorithm 4):

  1. norm_split     -- item norm-sort + top-n_top split       (sequential)
  2. item_codes     -- SA-ALSH partition/transform/SRP codes  (rows: items)
  3. user_blocking  -- cone-tree / "norm" blocking of users   (sequential)
  4. lower_bounds   -- Simpfer L_u / L_B over P'              (rows: users)

``build_sah_index`` here composes the SAME stage functions, adding two
things the core composition does not have: a per-stage wall-time breakdown
(``BuildTimings``) and optional mesh parallelism for the row-parallel
steps. Stage 2's SRP hashing is independent per item row and stage 4's
lower-bound GEMM + top_k is independent per user row (the m x n_top GEMM
is the dominant build cost at scale), so both steps shard over every mesh
axis via ``shard_map`` with dead zero-row padding when the row count does
not divide the device count (the PR-3 convention). Row slicing is bitwise
equal to the full-array computation for both steps, so:

  **invariant: the sharded build on any mesh produces a fingerprint-
  identical ``IndexArtifact`` to the single-device build** (pinned by
  tests/test_build.py, including prime row counts and 1x8 vs 2x4 meshes).

The sequential stages (sort, partition scan, cone tree) always run
replicated/single-device; they are cheap relative to the GEMMs and their
output feeds every shard anyway.

Sharding is selected by ``EngineConfig.build_sharding``:

  "auto"    -- shard when the policy carries a multi-device mesh (default);
  "single"  -- always run today's single-device path, even under a mesh;
  "sharded" -- require a multi-device mesh (ValueError otherwise).

``shards`` is a testing seam: it simulates the shard_map row slicing
in-process (pad, per-slice compute, concatenate) so single-device tests
can pin the bitwise-equality invariant for arbitrary shard counts without
a mesh; real meshes are covered by the subprocess tests.
"""

from __future__ import annotations

import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import sa_alsh as _alsh
from repro.core import sah as _sah
from repro.core import simpfer as _simpfer
from repro.dist.policy import NO_SHARDING, ShardingPolicy
from repro.engine.config import EngineConfig
from repro.kernels import ops as kops

BUILD_SHARDING_MODES = ("auto", "single", "sharded")


class BuildTimings(NamedTuple):
    """Wall seconds per build stage (compile included on first build)."""

    norm_split: float      # stage 1: item sort + top-n_top split
    item_codes: float      # stage 2: SA-ALSH partitions/transform/codes
    user_blocking: float   # stage 3: cone / norm blocking of users
    lower_bounds: float    # stage 4: Simpfer L_u / L_B over P'
    sharded: bool          # whether stages 2b/4 ran under shard_map

    @property
    def total(self) -> float:
        return (self.norm_split + self.item_codes + self.user_blocking
                + self.lower_bounds)

    def format(self) -> str:
        """One human-readable breakdown line (examples/quickstart.py)."""
        mode = "sharded" if self.sharded else "single-device"
        return (f"build {self.total * 1e3:.1f} ms ({mode}): "
                f"norm-split {self.norm_split * 1e3:.1f} | "
                f"item-codes {self.item_codes * 1e3:.1f} | "
                f"user-blocking {self.user_blocking * 1e3:.1f} | "
                f"lower-bounds {self.lower_bounds * 1e3:.1f}")


def validate_build_knobs(config: EngineConfig) -> None:
    """Reject unusable build knobs before any tracing happens.

    ``EngineConfig.__post_init__`` validates at construction, but configs
    can reach a build without re-running it (``object.__setattr__`` on the
    frozen instance, unpickled/manually wired objects, subclasses that
    skip init). The build entry points re-check the knobs that would
    otherwise surface as shape errors deep inside jitted stage bodies.
    """
    for name in ("k_max", "leaf_size", "n_bits", "tile", "max_partitions"):
        v = getattr(config, name)
        if not isinstance(v, int) or isinstance(v, bool) or v < 1:
            raise ValueError(f"build knob {name} must be a positive int, "
                             f"got {v!r}")
    if config.n_bits % 32 != 0:
        raise ValueError(f"build knob n_bits must be a multiple of 32, "
                         f"got {config.n_bits}")
    if config.n_top is not None and config.n_top < config.k_max:
        raise ValueError(f"build knob n_top ({config.n_top}) must be >= "
                         f"k_max ({config.k_max})")
    if getattr(config, "build_sharding", "auto") not in BUILD_SHARDING_MODES:
        raise ValueError(f"build_sharding must be one of "
                         f"{BUILD_SHARDING_MODES}, "
                         f"got {config.build_sharding!r}")


def _want_sharded(config: EngineConfig, policy: ShardingPolicy,
                  shards: int | None) -> bool:
    mode = config.build_sharding
    have = policy.device_count > 1 or (shards is not None and shards > 1)
    if mode == "single":
        return False
    if mode == "sharded":
        if not have:
            raise ValueError(
                "build_sharding='sharded' requires a multi-device mesh "
                "policy (or the `shards` testing seam); pass a mesh "
                "ShardingPolicy or use build_sharding='auto'")
        return True
    return have


def _pad_rows_zero(rows: jnp.ndarray, n_pad: int) -> jnp.ndarray:
    if n_pad == rows.shape[0]:
        return rows
    return jnp.concatenate(
        [rows, jnp.zeros((n_pad - rows.shape[0],) + rows.shape[1:],
                         rows.dtype)])


def row_parallel(fn, rows: jnp.ndarray, consts: tuple = (), *,
                 policy: ShardingPolicy = NO_SHARDING,
                 shards: int | None = None) -> jnp.ndarray:
    """Run a per-row function over row shards; bitwise == ``fn(rows, ...)``.

    ``fn(rows_slice, *consts) -> (r, ...)`` must be independent per row
    (row i of the output depends only on row i of the input and the
    replicated ``consts``). Rows are padded with dead zero rows to the
    next shard multiple and the padding is sliced off the gathered result,
    so any row count runs on any mesh (the PR-3 convention).

    With a mesh policy: one eager ``shard_map`` over every mesh axis (an
    outer jit around shard_map re-triggers the jax 0.4.x while-driver
    miscompile; the bodies here are embarrassingly parallel, but the
    engine-wide convention is eager dispatch). With ``shards``: the
    mesh-free simulation — per-slice compute + concatenate — used by the
    tests to pin the invariant in-process. Otherwise: ``fn`` unchanged.
    """
    if policy.mesh is not None and policy.device_count > 1:
        s = policy.device_count
        n = rows.shape[0]
        padded = _pad_rows_zero(rows, -(-n // s) * s)
        axes = tuple(policy.mesh.axis_names)
        row_spec = P(axes, *([None] * (rows.ndim - 1)))
        out = jax.shard_map(
            fn, mesh=policy.mesh,
            in_specs=(row_spec,) + tuple(P() for _ in consts),
            out_specs=P(axes, None), check_vma=False)(padded, *consts)
        # Gather to host layout before anything downstream touches the
        # result: the artifact contract is mesh-agnostic leaves, and eager
        # ops on an array still committed to the mesh run through implicit
        # GSPMD partitioning, which on jax 0.4.x can miscompile (the same
        # family as the outer-jit shard_map bug) — attach-time pad_index
        # on a committed block_lb was observed to corrupt real entries.
        return jnp.asarray(np.asarray(out)[:n])
    if shards is not None and shards > 1:
        n = rows.shape[0]
        padded = _pad_rows_zero(rows, -(-n // shards) * shards)
        per = padded.shape[0] // shards
        out = jnp.concatenate(
            [fn(padded[i * per:(i + 1) * per], *consts)
             for i in range(shards)])
        return out[:n]
    return fn(rows, *consts)


def build_sah_index(items: jnp.ndarray, users: jnp.ndarray,
                    key: jax.Array, *, config: EngineConfig,
                    policy: ShardingPolicy = NO_SHARDING,
                    shards: int | None = None
                    ) -> tuple[_sah.SAHIndex, BuildTimings]:
    """Algorithm 4 as the staged pipeline: (SAHIndex, BuildTimings).

    Composes the same stage functions as ``core/sah.py::build`` in the
    same order, so the single-device result is bitwise identical to
    ``sah.build(items, users, key, **config.build_kwargs())`` — and the
    sharded result is bitwise identical to the single-device one (module
    docstring). The returned index is host/mesh-agnostic; ``attach`` lays
    it out for a query mesh separately.
    """
    validate_build_knobs(config)
    sharded = _want_sharded(config, policy, shards)
    n_top = 2 * config.k_max if config.n_top is None else config.n_top
    k_idx, k_cone = _sah.build_keys(key)

    t0 = time.perf_counter()
    split = _sah.split_items_by_norm(items, n_top)
    jax.block_until_ready(split.rest)
    t1 = time.perf_counter()

    hash_rows = None
    if sharded:
        hash_rows = lambda rows, proj: row_parallel(
            kops.srp_hash, rows, (proj,), policy=policy, shards=shards)
    alsh = _alsh.build_index(split.rest, k_idx, b=config.b,
                             n_bits=config.n_bits, tile=config.tile,
                             max_partitions=config.max_partitions,
                             transform=config.transform,
                             hash_rows=hash_rows)
    alsh = _sah.shift_item_ids(alsh, split.order, n_top)
    jax.block_until_ready(alsh.codes)
    t2 = time.perf_counter()

    blocked = _sah.block_users(users, k_cone, leaf_size=config.leaf_size,
                               blocking=config.blocking)
    jax.block_until_ready(blocked.users)
    t3 = time.perf_counter()

    lb_rows = None
    if sharded:
        kmax = config.k_max
        lb_rows = lambda rows, top, _k: row_parallel(
            lambda r, t: _simpfer.user_lower_bounds_impl(r, t, kmax),
            rows, (top,), policy=policy, shards=shards)
    lb, block_lb = _sah.lower_bounds(blocked.users, blocked.user_mask,
                                     split.top_items, config.k_max,
                                     blocked.center.shape[0],
                                     lb_rows=lb_rows)
    jax.block_until_ready(lb)
    t4 = time.perf_counter()

    index = _sah.SAHIndex(alsh=alsh, users=blocked.users,
                          user_ids=blocked.user_ids,
                          user_mask=blocked.user_mask,
                          center=blocked.center, omega=blocked.omega,
                          theta=blocked.theta, user_lb=lb,
                          block_lb=block_lb, top_norms=split.top_norms,
                          top_items=split.top_items, top_ids=split.top_ids)
    timings = BuildTimings(norm_split=t1 - t0, item_codes=t2 - t1,
                           user_blocking=t3 - t2, lower_bounds=t4 - t3,
                           sharded=sharded)
    return index, timings
