"""The scan_precision="int8" contract (DESIGN.md SS13).

Pins the three promises of the quantized execute path: (1) the quantized
screen only ever over-admits — every f32 survivor is admitted and every
"definite" classification is a true survivor, for arbitrary corpora
(hypothesis, with fixed-seed mirrors for tier-1); (2) final predictions are
bitwise equal to the f32 path for every registry method, including
staged-delta and post-delete_items corpora and after compact(); (3) the
knob is execution-only — compile counts stay one trace per batch shape
across hot swaps and compaction (mirroring the f32 churn tests), the
artifact fingerprint ignores it, and attach accepts a precision mismatch.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as engine_mod
from repro.core import sa_alsh
from repro.data import synthetic
from repro.engine import (EngineConfig, IndexArtifact, RkMIPSEngine,
                          get_config)

try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False

D = 16
_BUILD_KEY = jax.random.PRNGKey(31)


@pytest.fixture(scope="module")
def workload():
    key = jax.random.PRNGKey(23)
    ki, kq = jax.random.split(key)
    items, users = synthetic.recommendation_data(ki, 120, 64, D)
    queries = synthetic.queries_from_items(kq, items, 4)
    return items, users, queries


def _cfg(method):
    return get_config(method).replace(tile=32, n_bits=32, k_max=8, n_top=8,
                                      leaf_size=8, n_cand=16,
                                      delta_capacity=8, serve_batch_size=2)


def _int8(cfg):
    return cfg.replace(scan_precision="int8")


# ---------------------------------------------------------------------------
# Knob semantics: validation, fingerprint/attach exclusion.
# ---------------------------------------------------------------------------


def test_scan_precision_validation_and_kwargs():
    with pytest.raises(ValueError, match="scan_precision"):
        EngineConfig(scan_precision="f16")
    assert EngineConfig().query_kwargs()["scan_precision"] == "f32"
    assert _int8(EngineConfig()).query_kwargs()["scan_precision"] == "int8"


def test_scan_precision_excluded_from_fingerprint_and_attach(workload):
    items, users, _ = workload
    cfg = _cfg("sah")
    a32 = IndexArtifact.build(items, users, _BUILD_KEY, config=cfg)
    a8 = IndexArtifact.build(items, users, _BUILD_KEY, config=_int8(cfg))
    assert a32.fingerprint == a8.fingerprint
    # an int8-scanning engine serves an f32-built artifact (and vice versa)
    RkMIPSEngine(_int8(cfg)).attach(a32)
    RkMIPSEngine(cfg).attach(a8)
    with pytest.raises(ValueError, match="does not match"):
        RkMIPSEngine(_int8(cfg).replace(n_cand=8)).attach(a32)


# ---------------------------------------------------------------------------
# Over-admission: the quantized screen never drops an f32 survivor.
# ---------------------------------------------------------------------------


def _screen_invariants(items, user, thr):
    """The SS13 classification on raw arrays: every f32 survivor is
    admitted by the quantized screen, and every definite beat is a true
    survivor — the band (admitted minus definite) is the only part that
    needs the exact re-rank."""
    items = jnp.asarray(items, jnp.float32)
    user = jnp.asarray(user, jnp.float32)
    d = items.shape[1]
    qitems, qscale = sa_alsh.quantize_rows(items)
    qips = (qitems.astype(jnp.float32) @ user) * qscale
    qerr = 0.5 * d ** 0.5 * sa_alsh._QERR_SLACK * qscale \
        * jnp.linalg.norm(user)
    survivors = np.asarray(items @ user > thr)
    admitted = np.asarray(qips + qerr > thr)
    definite = np.asarray(qips - qerr > thr)
    assert (admitted | ~survivors).all(), "screen dropped an f32 survivor"
    assert (survivors | ~definite).all(), "definite beat is not a survivor"


@pytest.mark.parametrize("seed", [0, 1, 7, 19])
def test_screen_over_admits_only(seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    items = jax.random.normal(ks[0], (200, 24)) * \
        jax.random.uniform(ks[1], (200, 1), minval=0.01, maxval=3.0)
    user = jax.random.normal(ks[2], (24,))
    user = user / jnp.linalg.norm(user)
    for thr in (-1.0, 0.0, 0.3, float(jnp.max(items @ user))):
        _screen_invariants(items, user, thr)


def test_screen_handles_zero_rows_and_scales():
    # all-zero rows quantize to scale 0: screen must classify them exactly
    items = jnp.concatenate([jnp.zeros((4, 8)), jnp.ones((4, 8))])
    user = jnp.ones((8,)) / jnp.sqrt(8.0)
    _screen_invariants(items, user, -0.5)
    _screen_invariants(items, user, 0.0)


# ---------------------------------------------------------------------------
# Bitwise equality for every registry method, deltas included.
# ---------------------------------------------------------------------------


def _assert_same_answers(e32, e8, queries, ks=(3, 8)):
    for k in ks:
        r32 = e32.query_batch(queries, k)
        r8 = e8.query_batch(queries, k)
        np.testing.assert_array_equal(np.asarray(r32.predictions),
                                      np.asarray(r8.predictions))
        # identical decisions imply identical scan trajectories too
        np.testing.assert_array_equal(np.asarray(r32.stats.tiles_scanned),
                                      np.asarray(r8.stats.tiles_scanned))


@pytest.mark.parametrize("method", engine_mod.method_names())
def test_int8_predictions_bitwise_equal(method, workload):
    items, users, queries = workload
    cfg = _cfg(method)
    art = IndexArtifact.build(items, users, _BUILD_KEY, config=cfg)
    e32 = RkMIPSEngine.from_artifact(art)
    e8 = RkMIPSEngine(_int8(cfg)).attach(art)
    _assert_same_answers(e32, e8, queries)
    # single-query facade rides the same dispatch
    np.testing.assert_array_equal(
        np.asarray(e32.query(queries[0], 5).predictions),
        np.asarray(e8.query(queries[0], 5).predictions))


@pytest.mark.parametrize("method", engine_mod.method_names())
def test_int8_bitwise_equal_under_churn(method, workload):
    """Staged-delta and post-delete corpora, then compact(): the int8
    path answers bitwise with the f32 path at every lifecycle stage."""
    items, users, queries = workload
    cfg = _cfg(method)
    key = jax.random.fold_in(_BUILD_KEY, 1)
    art = IndexArtifact.build(items, users, _BUILD_KEY, config=cfg)
    art = art.insert_items(jax.random.normal(key, (5, D)) * 1.2)
    art = art.delete_items([0, 7, 55, items.shape[0] + 1])
    e32 = RkMIPSEngine.from_artifact(art)
    e8 = RkMIPSEngine(_int8(cfg)).attach(art)
    _assert_same_answers(e32, e8, queries)
    compacted = art.compact()
    _assert_same_answers(RkMIPSEngine.from_artifact(compacted),
                         RkMIPSEngine(_int8(cfg)).attach(compacted),
                         queries)


def test_int8_forward_serving_delta_bitwise(workload):
    """The RetrievalServer's jitted merge consumes the artifact's persisted
    quantized twin (delta_qitems/delta_qscale) as an int8 screen on staged
    rows: over churned corpora — staged inserts, deletions, compact() —
    the int8 serving flush answers bitwise with the f32 flush, and the
    engine's ``kmips`` delta fold holds the same int8==f32 equality."""
    items, users, queries = workload
    cfg = _cfg("sah")
    key = jax.random.fold_in(_BUILD_KEY, 2)
    base = IndexArtifact.build(items, users, _BUILD_KEY, config=cfg)
    churned = base.insert_items(jax.random.normal(key, (5, D)) * 1.2)
    stages = [churned,
              churned.delete_items([3, 50, items.shape[0] + 2]),
              churned.compact()]
    s32 = RkMIPSEngine.from_artifact(base).server()
    s8 = RkMIPSEngine(_int8(cfg)).attach(base).server()
    for art in stages:
        s32.swap(art)
        s8.swap(art)
        e32 = RkMIPSEngine.from_artifact(art)
        e8 = RkMIPSEngine(_int8(cfg)).attach(art)
        for k in (3, 8):
            r32 = s32._flush_batch(list(queries[:2]), k)
            r8 = s8._flush_batch(list(queries[:2]), k)
            for a, b in zip(r32, r8):
                np.testing.assert_array_equal(np.asarray(a.values),
                                              np.asarray(b.values))
                np.testing.assert_array_equal(np.asarray(a.ids),
                                              np.asarray(b.ids))
            k32 = e32.kmips(queries[:2], k)
            k8 = e8.kmips(queries[:2], k)
            np.testing.assert_array_equal(np.asarray(k32.values),
                                          np.asarray(k8.values))
            np.testing.assert_array_equal(np.asarray(k32.ids),
                                          np.asarray(k8.ids))


# ---------------------------------------------------------------------------
# Compile counts: one trace per batch shape, unchanged by the knob.
# ---------------------------------------------------------------------------


def test_int8_churn_never_retraces(workload):
    """Mirror of tests/test_artifact.py::test_churn_never_retraces with
    scan_precision="int8": one executable for the plain pipeline, at most
    one more for the delta pipeline, reused across hot swaps, deletions
    and compact(); a new batch shape costs exactly one more."""
    items, users, queries = workload
    cfg = _int8(_cfg("sah"))
    art = IndexArtifact.build(items, users, _BUILD_KEY, config=cfg)
    eng = RkMIPSEngine.from_artifact(art)
    eng.query_batch(queries, 3)
    assert eng.rkmips_compile_count == 1
    eng.query_batch(queries, 3)
    assert eng.rkmips_compile_count == 1
    eng.attach(art.delete_items([1, 2]))          # delete-only: plain path
    eng.query_batch(queries, 3)
    assert eng.rkmips_compile_count == 1
    a = art.insert_items(jnp.ones((2, D)))
    eng.attach(a)                                  # the one extra compile
    eng.query_batch(queries, 3)
    assert eng.rkmips_compile_count == 2
    eng.attach(a.insert_items(jnp.ones((3, D))).delete_items([9]))
    eng.query_batch(queries, 3)
    assert eng.rkmips_compile_count == 2
    eng.attach(a.compact())                        # same padded shapes
    eng.query_batch(queries, 3)
    assert eng.rkmips_compile_count == 2
    eng.query_batch(queries[:2], 3)                # new batch shape
    assert eng.rkmips_compile_count == 3


# ---------------------------------------------------------------------------
# Hypothesis: arbitrary corpora (fixed-seed mirrors above keep tier-1).
# ---------------------------------------------------------------------------


if HAVE_HYP:
    hypothesis.settings.register_profile(
        "quantized", deadline=None, max_examples=25,
        suppress_health_check=[hypothesis.HealthCheck.too_slow,
                               hypothesis.HealthCheck.data_too_large])
    hypothesis.settings.load_profile("quantized")

    _floats = st.floats(-5.0, 5.0, allow_nan=False, width=32)

    @hypothesis.given(
        hnp.arrays(np.float32,
                   st.tuples(st.integers(1, 64), st.integers(1, 12)),
                   elements=_floats),
        st.integers(0, 2**16), st.floats(-3.0, 3.0, allow_nan=False))
    def test_screen_over_admits_only_property(p, seed, thr):
        user = jax.random.normal(jax.random.PRNGKey(seed), (p.shape[1],))
        norm = jnp.linalg.norm(user)
        user = jnp.where(norm > 0, user / jnp.maximum(norm, 1e-9), user)
        _screen_invariants(p, user, thr)

    @hypothesis.settings(max_examples=10)
    @hypothesis.given(st.integers(12, 60), st.integers(2, 10),
                      st.integers(0, 2**16))
    def test_decide_count_bitwise_property(m, d, seed):
        """decide_count int8 == f32 on arbitrary random corpora, both
        scans, across the full tau range (deep scans included)."""
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        items = jax.random.normal(ks[0], (m, d)) * \
            jax.random.uniform(ks[1], (m, 1), minval=0.05, maxval=2.0)
        users = jax.random.normal(ks[2], (5, d))
        users = users / jnp.linalg.norm(users, axis=-1, keepdims=True)
        idx = sa_alsh.build_index(items, ks[3], tile=16, n_bits=32,
                                  max_partitions=8)
        ips = users @ items.T
        taus = jnp.quantile(ips, jnp.linspace(0.1, 0.99, 5),
                            axis=-1).diagonal()
        init = jnp.zeros(5, jnp.int32)
        active = jnp.ones(5, bool)
        for scan in ("sketch", "exact"):
            a = sa_alsh.decide_count(idx, users, taus, init, active, 3,
                                     n_cand=8, scan=scan,
                                     scan_precision="f32")
            b = sa_alsh.decide_count(idx, users, taus, init, active, 3,
                                     n_cand=8, scan=scan,
                                     scan_precision="int8")
            np.testing.assert_array_equal(np.asarray(a[0]),
                                          np.asarray(b[0]))
            assert int(a[1]) == int(b[1])
