"""End-to-end behaviour tests: the SAH engine against the exact oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exact, metrics, sah
from repro.data import synthetic

EPS = 1e-5


@pytest.fixture(scope="module")
def workload():
    key = jax.random.PRNGKey(11)
    ki, kq, kb = jax.random.split(key, 3)
    items, users = synthetic.recommendation_data(ki, 2048, 4096, 48)
    norms = jnp.linalg.norm(items, axis=-1)
    order = jnp.argsort(-norms)
    queries = items[order[jax.random.choice(kq, 400, (6,), replace=False)]]
    uu = users / jnp.linalg.norm(users, axis=-1, keepdims=True)
    idx = sah.build(items, users, kb, k_max=50, n_bits=128, tile=256,
                    leaf_size=32)
    return items, users, uu, queries, idx


@pytest.mark.parametrize("k", [1, 10, 50])
def test_exact_scan_matches_oracle(workload, k):
    """scan='exact' is Simpfer's linear scan: must reproduce the oracle."""
    items, users, uu, queries, idx = workload
    truth = exact.rkmips_batch_chunked(items, uu, queries, k, tie_eps=EPS)
    pred, _ = sah.rkmips_batch(idx, queries, k, scan="exact", tie_eps=EPS)
    po = sah.predictions_to_original(idx, pred, users.shape[0])
    np.testing.assert_array_equal(np.asarray(po), np.asarray(truth))


@pytest.mark.parametrize("k", [1, 10])
def test_sketch_scan_f1(workload, k):
    """SA-ALSH sketch scan: approximate, F1 must stay high (paper: >0.9)."""
    items, users, uu, queries, idx = workload
    truth = exact.rkmips_batch_chunked(items, uu, queries, k, tie_eps=EPS)
    pred, _ = sah.rkmips_batch(idx, queries, k, scan="sketch", n_cand=64,
                               tie_eps=EPS)
    po = sah.predictions_to_original(idx, pred, users.shape[0])
    f1 = float(jnp.mean(metrics.f1_score(po, truth)))
    assert f1 > 0.9, f1


def test_sketch_error_is_one_sided(workload):
    """Sketch candidate misses can only under-count beating items, which can
    only flip a correct 'no' into a false 'yes' -- never the reverse. So the
    sketch prediction set must contain every true positive."""
    items, users, uu, queries, idx = workload
    k = 10
    truth = exact.rkmips_batch_chunked(items, uu, queries, k, tie_eps=EPS)
    pred, _ = sah.rkmips_batch(idx, queries, k, scan="sketch", n_cand=64,
                               tie_eps=EPS)
    po = sah.predictions_to_original(idx, pred, users.shape[0])
    assert bool(jnp.all(~truth | po))


def test_batch_matches_single(workload):
    items, users, uu, queries, idx = workload
    k = 10
    batch_pred, _ = sah.rkmips_batch(idx, queries, k, scan="exact",
                                     tie_eps=EPS)
    for i in range(2):
        single, _ = sah.rkmips(idx, queries[i], k, scan="exact", tie_eps=EPS)
        np.testing.assert_array_equal(np.asarray(single),
                                      np.asarray(batch_pred[i]))


def test_batch_matches_single_sketch(workload):
    """rkmips_batch drives one flat cross-query work queue (DESIGN.md SS9):
    predictions and the plan-time counters must be bitwise identical per
    query under the sketch scan too (regression for the chunked while-loop
    driver; chunks/tiles are packing diagnostics of the mixed-query queue
    and are pinned for nq=1 in tests/test_batched.py)."""
    items, users, uu, queries, idx = workload
    k = 10
    batch_pred, batch_stats = sah.rkmips_batch(idx, queries, k,
                                               scan="sketch", n_cand=64,
                                               tie_eps=EPS)
    for i in range(queries.shape[0]):
        single, stats = sah.rkmips(idx, queries[i], k, scan="sketch",
                                   n_cand=64, tie_eps=EPS)
        np.testing.assert_array_equal(np.asarray(single),
                                      np.asarray(batch_pred[i]))
        for f in ("blocks_alive", "users_alive", "n_no_lb", "n_yes_norm",
                  "n_scan"):
            assert int(getattr(stats, f)) == \
                int(np.asarray(getattr(batch_stats, f))[i]), f


def test_predictions_to_original_roundtrip():
    """Leaf-order -> original-row mapping: row u is True iff any real
    (non-padding) leaf slot of u is True; padding duplicates (user_mask
    False) never leak into the original rows. m=50 with leaf_size=16 pads
    to 64 slots, so 14 slots are cyclic duplicates of real users."""
    key = jax.random.PRNGKey(3)
    ki, ku = jax.random.split(key)
    items = jax.random.normal(ki, (256, 12))
    users = jax.random.normal(ku, (50, 12))
    idx = sah.build(items, users, key, k_max=5, n_bits=32, tile=64,
                    leaf_size=16)
    m = users.shape[0]
    user_ids = np.asarray(idx.user_ids)
    mask = np.asarray(idx.user_mask)
    assert not mask.all()                     # padding duplicates exist

    rng = np.random.default_rng(7)
    pred = jnp.asarray(rng.random(idx.n_users) < 0.3)
    po = np.asarray(sah.predictions_to_original(idx, pred, m))
    expect = np.zeros(m, bool)
    np.logical_or.at(expect, user_ids, np.asarray(pred) & mask)
    np.testing.assert_array_equal(po, expect)

    # Padding-only positives must collapse to an all-False original view.
    pad_only = jnp.asarray(~mask)
    po_pad = np.asarray(sah.predictions_to_original(idx, pad_only, m))
    assert not po_pad.any()

    # Batched leading dims map row-wise.
    pred2 = jnp.stack([pred, ~pred])
    po2 = np.asarray(sah.predictions_to_original(idx, pred2, m))
    np.testing.assert_array_equal(po2[0], po)


def test_query_stats_consistent(workload):
    items, users, uu, queries, idx = workload
    pred, stats = sah.rkmips_batch(idx, queries, 10, scan="exact",
                                   tie_eps=EPS)
    m_real = int(jnp.sum(idx.user_mask))
    assert m_real == users.shape[0]
    s = jax.tree.map(np.asarray, stats)
    assert (s.blocks_alive <= idx.n_blocks).all()
    assert (s.n_scan <= s.users_alive).all()
    assert (s.n_yes_norm + s.n_no_lb <= m_real).all()
