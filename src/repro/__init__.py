"""repro: a production-grade JAX framework reproducing and extending

SAH: Shifting-aware Asymmetric Hashing for Reverse k-Maximum Inner Product
Search (Huang, Wang, Tung; AAAI 2023).

Layers:
  core/     the paper's contribution (SAT, SA-ALSH, cone blocking, SAH engine)
  kernels/  Pallas TPU kernels for the compute hot spots (hamming scan, srp hash,
            fused ip+topk) with jnp oracles
  models/   LM transformers (dense + MoE), GAT, recsys models
  data/     synthetic data pipelines, graph sampler
  train/    optimizer, trainer, checkpointing, compression
  dist/     sharding policies, distributed decode, collective helpers
  configs/  assigned architecture configs
  launch/   mesh, dry-run, train/serve drivers
"""

__version__ = "1.0.0"

# Installing the dist compat aliases here (not only in repro.dist) means any
# entry point -- launchers, benchmarks, subprocess test scripts -- sees the
# modern jax.shard_map/jax.make_mesh API regardless of which submodule it
# imports first. No device state is touched (see launch/mesh.py).
from repro.dist import compat as _dist_compat

_dist_compat.install()
del _dist_compat

# The engine registry is the package's front door (DESIGN.md SS7): every
# paper baseline is a named preset config of one RkMIPSEngine. Re-exported
# lazily (PEP 562): the engine pulls in repro.core, whose module-level jnp
# constants initialize the jax backend — and `python -m repro.launch.dryrun`
# runs this package init BEFORE it can set the fake-device-count flag, so
# `import repro` must stay backend-free (SS1).
__all__ = [
    "EngineConfig",
    "IndexArtifact",
    "PAPER_BASELINES",
    "RkMIPSEngine",
    "ServingRuntime",
    "TicketExpired",
    "display_name",
    "get_config",
    "load_artifact",
    "method_names",
    "register",
]


def __getattr__(name):
    if name in __all__:
        from repro import engine as _engine
        return getattr(_engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
