"""SA-ALSH: Shifting-Aware Asymmetric LSH index and query scans.

Faithful to Algorithms 1-2 of the paper, adapted to TPU dataflow as described
in DESIGN.md SS2:

  * items are sorted by descending l2-norm and partitioned into norm ranges
    (b*M_j, M_j] (Algorithm 1 lines 3-6);
  * each partition's items are SAT-transformed with its own centroid/radius
    (lines 7-11) and hashed with SRP; codes are bit-packed uint32 sketches
    rather than hash-table buckets (Hamming ranking == collision-count
    ranking in expectation, DESIGN.md SS2);
  * the query phase walks fixed-size, norm-ordered tiles with the
    Cauchy-Schwarz bound mu = max_norm_tile * ||u|| for early termination
    (Algorithm 2 lines 3-4), selecting candidates per tile by Hamming
    distance and re-ranking them with exact inner products.

Because the user transform U(u) = [lambda*u; 0] has a zero appended coordinate
and lambda > 0, a user's SRP code is sign(u @ proj[:d]) -- one code per user,
valid against every partition's item codes. All per-partition state is baked
into the item codes at indexing time.

Two query entry points:
  kmips_topk     -- approximate top-k MIPS (paper's Algorithm 2), used for the
                    kMIPS benchmarks (Fig. 6) and standalone retrieval.
  decide_count   -- the RkMIPS decision primitive: counts items with
                    <u, p> > tau until count >= k ("no") or the norm bound
                    certifies no further item can beat tau ("yes"). This is
                    Algorithm 2 reformulated as counting, which is exactly the
                    decision Algorithm 5 needs (see core/sah.py).

Both support scan="sketch" (SA-ALSH) and scan="exact" (Simpfer's linear scan
with the same early-termination rule), which gives the paper's baselines for
free.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import partitions as _parts
from repro.core import srp as _srp
from repro.core import transforms as _tf
from repro.kernels import ops as kops

_NEG = -jnp.inf
_BIG_HAMMING = jnp.int32(1 << 30)


class SAALSHIndex(NamedTuple):
    """Index over items sorted by descending norm, padded to a tile multiple.

    Attributes:
      items:      (n_pad, d) f32, descending-norm order, zero rows for padding.
      item_ids:   (n_pad,) int32, original item row; -1 for padding.
      norms:      (n_pad,) f32, descending; 0 for padding.
      item_mask:  (n_pad,) bool.
      codes:      (n_pad, W) uint32 SRP sketch of the per-partition
                  asymmetric transform of each item.
      proj:       (d+1, B) f32 shared SRP projection (rows 0..d-1 hash the
                  shifted item / the user; row d hashes the appended coord).
      part_id:    (n_pad,) int32 partition of each item.
      part_max_norm: (T,) f32 M_j per partition (0 padding).
      part_centroid: (T, d) f32 c_j.
      part_radius:   (T,) f32 R_j.
      n_parts:    () int32.
      tile_max_norm: (n_tiles,) f32 max norm *within* each tile; because the
                  global order is norm-descending, tile t's max also bounds
                  every row of every later tile t' > t, which is what makes
                  it the scan's early-termination bound.
      qitems:     (n_pad, d) int8 per-partition symmetric quantization of
                  ``items`` (DESIGN.md SS13): row i is
                  round(items[i] / qscale[i]), zero for padding. The
                  ``scan_precision="int8"`` screen reads these instead of
                  the f32 rows (~4x less bandwidth on the scan hot path).
      qscale:     (n_pad,) f32 dequantization scale of each row -- shared
                  within a partition (max |coord| in the partition / 127),
                  stored per row so candidate gathers need no second
                  ``part_id`` indirection; 0 for padding and all-zero
                  partitions.
    """

    items: jnp.ndarray
    item_ids: jnp.ndarray
    norms: jnp.ndarray
    item_mask: jnp.ndarray
    codes: jnp.ndarray
    proj: jnp.ndarray
    part_id: jnp.ndarray
    part_max_norm: jnp.ndarray
    part_centroid: jnp.ndarray
    part_radius: jnp.ndarray
    n_parts: jnp.ndarray
    tile_max_norm: jnp.ndarray
    qitems: jnp.ndarray
    qscale: jnp.ndarray

    @property
    def tile(self) -> int:
        return self.items.shape[0] // self.tile_max_norm.shape[0]

    @property
    def dim(self) -> int:
        return self.items.shape[1]


def _pad_rows(x: jnp.ndarray, n_pad: int, fill=0):
    pad = n_pad - x.shape[0]
    if pad == 0:
        return x
    widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=fill)


def _quantize_with_scale(rows: jnp.ndarray, scale: jnp.ndarray):
    """round(rows / scale) as int8; all-zero rows (scale 0) quantize to 0."""
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(rows / safe[:, None]), -127.0, 127.0)
    return q.astype(jnp.int8)


def quantize_rows(rows: jnp.ndarray):
    """Per-row symmetric int8 quantization: ``(qrows int8, scale f32)``.

    ``scale[i] = max|rows[i]| / 127`` (0 for an all-zero row, which
    quantizes to zeros). This is the staged-delta convention
    (engine/artifact.py::insert_items): delta rows have no norm partition,
    so each carries its own scale -- the error ball
    0.5 * scale * sqrt(d) * ||u|| (see ``decide_count``) holds per row
    either way.
    """
    scale = jnp.max(jnp.abs(rows), axis=-1) / 127.0
    return _quantize_with_scale(rows, scale), scale.astype(jnp.float32)


def quantize_partitioned(rows: jnp.ndarray, part_id: jnp.ndarray,
                         max_partitions: int):
    """Per-partition symmetric int8 quantization: ``(qrows, scale)`` with
    one shared scale per norm partition (max |coord| in the partition /
    127), broadcast back to a per-row (n,) array. Coarser than per-row --
    the scan gathers one scale per candidate with no ``part_id``
    indirection, and a partition's rows stay mutually comparable in code
    space."""
    absmax = jnp.max(jnp.abs(rows), axis=-1)
    pmax = jax.ops.segment_max(absmax, part_id,
                               num_segments=max_partitions)
    pmax = jnp.where(pmax > 0, pmax, 0.0)     # empty segments hold -inf
    scale = (pmax / 127.0)[part_id]
    return _quantize_with_scale(rows, scale), scale.astype(jnp.float32)


class PreparedItems(NamedTuple):
    """Item-side build state minus the SRP codes (stage 2a of the staged
    build pipeline, DESIGN.md SS11).

    Everything here is the output of one jitted, sequential computation
    (norm sort + partition scan + asymmetric transform). What remains --
    hashing ``transformed`` row-by-row against a projection -- is
    embarrassingly row-parallel, so the staged pipeline
    (``engine/build.py``) shards exactly that step over the mesh.

    All row-shaped fields are already padded to ``n_pad`` rows; padding
    rows of ``transformed`` are zero, so their codes are the hash of the
    zero vector no matter how rows are sharded.
    """

    items: jnp.ndarray          # (n_pad, d) descending-norm order
    item_ids: jnp.ndarray       # (n_pad,) int32, -1 padding
    norms: jnp.ndarray          # (n_pad,) f32
    item_mask: jnp.ndarray      # (n_pad,) bool
    part_id: jnp.ndarray        # (n_pad,) int32
    part_max_norm: jnp.ndarray  # (T,) f32
    part_centroid: jnp.ndarray  # (T, d) f32
    part_radius: jnp.ndarray    # (T,) f32
    n_parts: jnp.ndarray        # () int32
    tile_max_norm: jnp.ndarray  # (n_tiles,) f32
    transformed: jnp.ndarray    # (n_pad, d+1) f32 rows to hash; 0 padding
    qitems: jnp.ndarray         # (n_pad, d) int8 quantized rows; 0 padding
    qscale: jnp.ndarray         # (n_pad,) f32 per-row dequant scale


@functools.partial(jax.jit,
                   static_argnames=("b", "max_partitions", "tile",
                                    "transform", "n_pad"))
def _prepare(items, *, b, max_partitions, tile, transform, n_pad):
    n, d = items.shape
    norms = jnp.linalg.norm(items, axis=-1)
    order = jnp.argsort(-norms)
    items_sorted = items[order]
    norms_sorted = norms[order]

    parts = _parts.build_partitions(items_sorted, norms_sorted, b,
                                    max_partitions)

    # Per-item asymmetric transform using its partition's centroid / scale.
    if transform == "sat":
        c = parts.centroid[parts.part_id]                 # (n, d)
        r = parts.radius[parts.part_id]                   # (n,)
        shifted = items_sorted - c
        ext2 = jnp.maximum(r ** 2 - jnp.sum(shifted * shifted, -1), 0.0)
    elif transform == "qnf":
        shifted = items_sorted
        m = parts.max_norm[parts.part_id]
        ext2 = jnp.maximum(m ** 2 - norms_sorted ** 2, 0.0)
    else:
        raise ValueError(f"unknown transform {transform!r}")
    transformed = jnp.concatenate([shifted, jnp.sqrt(ext2)[:, None]], -1)

    item_mask = _pad_rows(jnp.ones((n,), bool), n_pad)
    norms_p = _pad_rows(norms_sorted, n_pad)
    tile_max = jnp.max(norms_p.reshape(-1, tile), axis=-1)
    qitems, qscale = quantize_partitioned(items_sorted, parts.part_id,
                                          max_partitions)

    return PreparedItems(
        items=_pad_rows(items_sorted, n_pad),
        item_ids=_pad_rows(order.astype(jnp.int32), n_pad, fill=-1),
        norms=norms_p,
        item_mask=item_mask,
        part_id=_pad_rows(parts.part_id, n_pad, fill=max_partitions - 1),
        part_max_norm=parts.max_norm,
        part_centroid=parts.centroid,
        part_radius=parts.radius,
        n_parts=parts.n_parts,
        tile_max_norm=tile_max,
        transformed=_pad_rows(transformed, n_pad),
        qitems=_pad_rows(qitems, n_pad),
        qscale=_pad_rows(qscale, n_pad),
    )


def prepare_items(items: jnp.ndarray, *, b: float = 0.5,
                  max_partitions: int = 64, tile: int = 512,
                  transform: str = "sat") -> PreparedItems:
    """Stage 2a: norm-sort, partition and transform items (no hashing)."""
    n = items.shape[0]
    n_pad = -(-n // tile) * tile
    return _prepare(items, b=b, max_partitions=max_partitions, tile=tile,
                    transform=transform, n_pad=n_pad)


def assemble_index(prep: PreparedItems, codes: jnp.ndarray,
                   proj: jnp.ndarray) -> SAALSHIndex:
    """Stage 2c: combine prepared item state with its SRP codes."""
    return SAALSHIndex(
        items=prep.items,
        item_ids=prep.item_ids,
        norms=prep.norms,
        item_mask=prep.item_mask,
        codes=codes,
        proj=proj,
        part_id=prep.part_id,
        part_max_norm=prep.part_max_norm,
        part_centroid=prep.part_centroid,
        part_radius=prep.part_radius,
        n_parts=prep.n_parts,
        tile_max_norm=prep.tile_max_norm,
        qitems=prep.qitems,
        qscale=prep.qscale,
    )


def build_index(items: jnp.ndarray, key: jax.Array, *, b: float = 0.5,
                n_bits: int = 128, max_partitions: int = 64,
                tile: int = 512, transform: str = "sat",
                hash_rows: Callable[[jnp.ndarray, jnp.ndarray],
                                    jnp.ndarray] | None = None
                ) -> SAALSHIndex:
    """Build an SA-ALSH (transform="sat") or H2-ALSH-style (="qnf") index.

    hash_rows(rows, proj) -> codes overrides the SRP hashing step (stage
    2b); the staged build pipeline passes a mesh-sharded row hasher here.
    Row hashing is independent per row, so any row-sliced hasher is
    bitwise equal to the default full-array ``kops.srp_hash``.
    """
    prep = prepare_items(items, b=b, max_partitions=max_partitions,
                         tile=tile, transform=transform)
    proj = _srp.make_projection(key, items.shape[1] + 1, n_bits)
    codes = (hash_rows or kops.srp_hash)(prep.transformed, proj)
    return assemble_index(prep, codes, proj)


def user_codes(index: SAALSHIndex, users: jnp.ndarray) -> jnp.ndarray:
    """SRP codes of user/query vectors: sign(u @ proj[:d]). (m, d)->(m, W)."""
    return kops.srp_hash(users, index.proj[:-1])


# ---------------------------------------------------------------------------
# Tile scans.
# ---------------------------------------------------------------------------


def _tile_slice(arr: jnp.ndarray, t: jnp.ndarray, tile: int) -> jnp.ndarray:
    start = (t * tile,) + (0,) * (arr.ndim - 1)
    size = (tile,) + arr.shape[1:]
    return jax.lax.dynamic_slice(arr, start, size)


def _tile_candidates(index: SAALSHIndex, ucodes, users, t, *, n_cand: int,
                     scan: str):
    """Exact IPs of the top-n_cand sketch candidates in tile t.

    Returns (ips (C, c), valid (C, c) bool, local (C, c) int32 tile-local
    candidate rows). scan="exact" treats the whole tile as candidates
    (c == tile).
    """
    tile = index.tile
    items_t = _tile_slice(index.items, t, tile)          # (tile, d)
    mask_t = _tile_slice(index.item_mask, t, tile)       # (tile,)
    if scan == "exact":
        ips = users @ items_t.T                          # (C, tile)
        local = jnp.broadcast_to(
            jnp.arange(tile, dtype=jnp.int32)[None, :], ips.shape)
        return ips, jnp.broadcast_to(mask_t[None, :], ips.shape), local
    codes_t = _tile_slice(index.codes, t, tile)          # (tile, W)
    dist = kops.hamming_scores(ucodes, codes_t)          # (C, tile)
    dist = jnp.where(mask_t[None, :], dist, _BIG_HAMMING)
    _, cand = jax.lax.top_k(-dist, n_cand)               # (C, n_cand)
    cand_vecs = jnp.take(items_t, cand, axis=0)          # (C, n_cand, d)
    ips = jnp.einsum("cnd,cd->cn", cand_vecs, users)
    valid = jnp.take(mask_t, cand, axis=0)
    return ips, valid, cand.astype(jnp.int32)


# Headroom multiplier on the quantization error ball: the ball bounds the
# *real-arithmetic* rounding residual; the extra 1% covers the f32 rounding
# of both the dequantized and the exact inner-product evaluations (each is
# ~127 * d * eps_f32 relative to the ball's own radius, < 0.5% at d = 4096).
_QERR_SLACK = 1.01

_SCAN_PRECISIONS = ("f32", "int8")


def _tile_beat_int8(index: SAALSHIndex, ucodes, users, unorm, thr, t, *,
                    n_cand: int, scan: str):
    """Per-lane survivor count of tile t under the quantized screen
    (DESIGN.md SS13) -- bitwise the f32 scan's count.

    Candidates are classified against ``thr`` with their dequantized int8
    inner products and the conservative error ball
    ``qerr = 0.5 * scale * sqrt(d) * ||u|| * slack`` (Cauchy-Schwarz on the
    per-coordinate rounding residual |delta_i| <= scale/2): a *definite*
    beat (qips - qerr > thr) counts immediately, a definite miss
    (qips + qerr <= thr) drops, and only the band in between is re-ranked
    with exact f32 rows. The ball can only widen the band (over-admission),
    never misclassify, so the count matches the f32 path's.
    """
    tile = index.tile
    radius = 0.5 * float(index.dim) ** 0.5 * _QERR_SLACK
    items_t = _tile_slice(index.items, t, tile)           # (tile, d)
    mask_t = _tile_slice(index.item_mask, t, tile)        # (tile,)
    qitems_t = _tile_slice(index.qitems, t, tile)         # (tile, d)
    qscale_t = _tile_slice(index.qscale, t, tile)         # (tile,)
    if scan == "exact":
        # Dense quantized screen over the whole tile. The band re-ranks
        # against the SAME (C, tile) f32 GEMM the f32 path computes (a
        # gathered-row einsum is not bitwise-stable against a GEMM), so
        # exact-scan int8 exercises the screen as a correctness mode; the
        # bandwidth win lives on the sketch path, where the exact re-rank
        # touches only the band rows.
        qips = (users @ qitems_t.T.astype(jnp.float32)) * qscale_t[None, :]
        qerr = (radius * qscale_t)[None, :] * unorm[:, None]
        valid = mask_t[None, :]
        definite = valid & (qips - qerr > thr[:, None])
        band = valid & ~definite & (qips + qerr > thr[:, None])
        ips = users @ items_t.T
        return (jnp.sum(definite, axis=-1)
                + jnp.sum(band & (ips > thr[:, None]), axis=-1))

    codes_t = _tile_slice(index.codes, t, tile)
    cand, qips = kops.fused_scan(ucodes, codes_t, mask_t, qitems_t,
                                 qscale_t, users, n_cand=n_cand)
    valid = jnp.take(mask_t, cand, axis=0)                # (C, n_cand)
    qerr = radius * jnp.take(qscale_t, cand, axis=0) * unorm[:, None]
    definite = valid & (qips - qerr > thr[:, None])
    band = valid & ~definite & (qips + qerr > thr[:, None])
    count = jnp.sum(definite, axis=-1)

    # Exact f32 re-rank of the band, <= s_slots rows per lane per pass
    # (one pass in practice: the band is the thin shell |ip - thr| < qerr).
    # s_slots >= 8 keeps the gathered (C, s, d) einsum bitwise equal to the
    # f32 path's (C, n_cand, d) einsum on this backend -- pinned by
    # tests/test_kernels.py::test_band_einsum_bitwise_stable; s == n_cand
    # is the identical shape outright.
    s_slots = min(16, n_cand)

    def have_band(state):
        left, _ = state
        return jnp.any(left)

    def rerank(state):
        left, c = state
        prio, pos = jax.lax.top_k(left.astype(jnp.int32), s_slots)
        real = prio > 0
        rows = jnp.take_along_axis(cand, pos, axis=-1)    # (C, s)
        vecs = jnp.take(items_t, rows, axis=0)            # (C, s, d)
        eips = jnp.einsum("cnd,cd->cn", vecs, users)
        c = c + jnp.sum(real & (eips > thr[:, None]), axis=-1)
        hit = jax.nn.one_hot(pos, n_cand, dtype=bool) & real[..., None]
        return left & ~jnp.any(hit, axis=-2), c

    _, band_count = jax.lax.while_loop(
        have_band, rerank, (band, jnp.zeros_like(count)))
    return count + band_count


def decide_count_impl(index: SAALSHIndex, users: jnp.ndarray,
                      taus: jnp.ndarray, init_count: jnp.ndarray,
                      active: jnp.ndarray, k: int, *, n_cand: int = 64,
                      scan: str = "sketch", eps: jnp.ndarray | float = 0.0,
                      scan_precision: str = "f32"):
    """RkMIPS decision for a chunk of user lanes against their thresholds.

    users (C, d) -- unit user vectors; taus (C,) = <u, q>; init_count (C,) --
    items already known to beat tau (from the Simpfer lower-bound arrays over
    the top-norm item set P'); active (C,) -- lanes that actually need work;
    eps -- absolute tie tolerance (see core/exact.py), a scalar or a (C,)
    per-lane array.

    Lanes are fully independent: each carries its own tau, its own eps and
    (through tau) its own early-exit bound, so a chunk may mix lanes from
    *different* RkMIPS queries -- the batched flat work queue of
    core/sah.py::rkmips_execute packs mixed-query chunks through this one
    function. (The query vector itself never appears here: it reaches the
    decision only via tau = <u, q>, and the Cauchy-Schwarz tile bound
    mu = max_norm_tile * ||u|| is query-free because users are unit.)
    A lane's outcome depends only on its own (user, tau, count, eps), never
    on which other lanes share the chunk.

    Returns (is_yes (C,), tiles_visited ()) where is_yes[i] means q stays in
    u_i's top-k. Decision rule (Definition 1, strict-count convention):
      no  <=> #{p : <u,p> > tau + eps} >= k
      yes <=> scan exhausted / bound mu_tile <= tau with count < k.

    scan_precision selects the tile screen (DESIGN.md SS13): "f32" (the
    stock float scan) or "int8" (the quantized screen + banded exact
    re-rank of ``_tile_beat_int8``, fed by the fused kernel
    ``repro.kernels.fused_scan``). Execution-only: both produce bitwise
    identical decisions, the early-exit bound and the tile walk are
    precision-independent, and the plan phase never sees the knob.

    This is the undecorated body; call ``decide_count`` (the jitted alias)
    directly. The impl exists for composition inside outer transforms --
    the batched driver traces it raw so the whole query phase stays a
    single-jit computation that is safe under ``shard_map`` (DESIGN.md SS9).
    """
    if scan_precision not in _SCAN_PRECISIONS:
        raise ValueError(f"scan_precision must be one of {_SCAN_PRECISIONS},"
                         f" got {scan_precision!r}")
    n_tiles = index.tile_max_norm.shape[0]
    n_cand_eff = index.tile if scan == "exact" else n_cand
    ucodes = user_codes(index, users) if scan == "sketch" else \
        jnp.zeros((users.shape[0], index.codes.shape[1]), jnp.uint32)
    # (taus + eps) broadcasts for scalar and per-lane eps alike, and is
    # bitwise the f32 additions the scalar-eps form performed.
    thr = taus + eps                                      # (C,)
    unorm = (jnp.linalg.norm(users, axis=-1)
             if scan_precision == "int8" else None)

    def cond(state):
        t, count, undecided = state
        return (t < n_tiles) & jnp.any(undecided)

    def body(state):
        t, count, undecided = state
        mu = index.tile_max_norm[t]                       # scalar bound
        # Lanes whose tau already dominates the bound are decided "yes".
        bound_done = mu <= taus
        still = undecided & ~bound_done
        if scan_precision == "int8":
            beat = _tile_beat_int8(index, ucodes, users, unorm, thr, t,
                                   n_cand=n_cand_eff, scan=scan)
        else:
            ips, valid, _ = _tile_candidates(index, ucodes, users, t,
                                             n_cand=n_cand_eff, scan=scan)
            beat = jnp.sum((ips > thr[:, None]) & valid, axis=-1)
        count = count + jnp.where(still, beat, 0)
        undecided = still & (count < k)
        return t + 1, count, undecided

    count0 = jnp.where(active, init_count, k)             # inactive: decided
    undecided0 = active & (count0 < k)
    t_fin, count_fin, _ = jax.lax.while_loop(
        cond, body, (jnp.asarray(0, jnp.int32), count0, undecided0))
    is_yes = active & (count_fin < k)
    return is_yes, t_fin


decide_count = functools.partial(
    jax.jit, static_argnames=("k", "n_cand", "scan", "scan_precision"),
)(decide_count_impl)


@functools.partial(jax.jit, static_argnames=("k",))
def merge_topk(vals: jnp.ndarray, ids: jnp.ndarray,
               extra_vals: jnp.ndarray, extra_ids: jnp.ndarray, k: int):
    """Row-wise merge of two candidate sets into one descending top-k.

    vals/ids (Q, a) and extra_vals/extra_ids (Q, b) -> (Q, k) each. Dead
    candidates must carry ``-inf`` values (and whatever sentinel id). Used
    by the engine to fold the exactly-scanned staged-insert delta buffer
    into a main-index kMIPS answer (engine/artifact.py), and generic
    enough for any local-top-k combination.
    """
    merged_v = jnp.concatenate([vals, extra_vals], axis=-1)
    merged_i = jnp.concatenate([ids, extra_ids], axis=-1)
    best, pos = jax.lax.top_k(merged_v, k)
    return best, jnp.take_along_axis(merged_i, pos, axis=-1)


def merge_delta_topk(vals: jnp.ndarray, ids: jnp.ndarray,
                     queries: jnp.ndarray, d_items: jnp.ndarray,
                     d_mask: jnp.ndarray, k: int, n_base: int, *,
                     d_qitems: jnp.ndarray | None = None,
                     d_qscale: jnp.ndarray | None = None,
                     scan_precision: str = "f32"):
    """Fold the staged-insert delta buffer into a main-index top-k answer.

    vals/ids (Q, k) -- the main scan's descending top-k; queries (Q, d);
    d_items (cap, d) staged rows with liveness d_mask (cap,). Staged row j
    gets id ``n_base + j``. This is THE forward delta merge: the engine's
    ``kmips`` and the RetrievalServer's jitted merge both route through it,
    so the two surfaces can never disagree id-for-id (DESIGN.md SS10).

    ``scan_precision="int8"`` screens the buffer with its persisted
    quantized twin (``d_qitems``/``d_qscale``, per-row scales --
    engine/artifact.py stamps them at insert) before touching f32: a row
    whose dequantized IP plus the Cauchy-Schwarz error ball
    ``0.5 * sqrt(d) * slack * scale * ||q||`` cannot beat the main scan's
    k-th value is dropped outright -- it provably cannot displace any
    incumbent (ties break toward earlier positions, and the main top-k
    concatenates first). Only surviving band rows are scored in f32, by
    the *same* GEMM expression the f32 path uses, skipped entirely
    (``lax.cond``) when that query's band screens clean -- so the merged
    answer is BITWISE the f32 merge, and the screen may only over-admit
    (the SS13 contract, applied to the delta buffer).

    The f32 scoring maps over queries (``lax.map``) for the same reason
    as the main scan (engine/sharding.py): a batched contraction's
    per-row low bits vary with Q, and the serving bucket ladder dispatches
    this merge at every rung — bitwise rung-equality (DESIGN.md SS14)
    needs per-query bodies whose shapes never see Q.
    """
    if scan_precision not in _SCAN_PRECISIONS:
        raise ValueError(f"scan_precision must be one of {_SCAN_PRECISIONS},"
                         f" got {scan_precision!r}")
    if scan_precision == "int8":
        if d_qitems is None or d_qscale is None:
            raise ValueError("int8 delta merge needs the quantized buffer: "
                             "pass d_qitems/d_qscale "
                             "(artifact.kmips_delta_quantized)")
        radius = 0.5 * float(queries.shape[-1]) ** 0.5 * _QERR_SLACK
        qitems_f32 = d_qitems.astype(jnp.float32)

        def one_screened(args):
            q, v = args                                  # (d,), (k,)
            qips = (qitems_f32 @ q) * d_qscale
            qerr = radius * d_qscale * jnp.linalg.norm(q)
            band = d_mask & (qips + qerr > v[k - 1])
            ips = jax.lax.cond(
                jnp.any(band),
                lambda: d_items @ q,
                lambda: jnp.zeros((d_items.shape[0],), vals.dtype))
            return jnp.where(band, ips, -jnp.inf)
        d_vals = jax.lax.map(one_screened, (queries, vals))
    else:
        d_vals = jax.lax.map(
            lambda q: jnp.where(d_mask, d_items @ q, -jnp.inf), queries)
    d_ids = jnp.broadcast_to(
        n_base + jnp.arange(d_items.shape[0], dtype=ids.dtype),
        d_vals.shape)
    return merge_topk(vals, ids, d_vals, d_ids, k)


def delta_screen_tables(users: jnp.ndarray, d_qitems: jnp.ndarray,
                        d_qscale: jnp.ndarray):
    """Query-independent int8 screen tables for the staged delta buffer in
    the *reverse* plan (sah.py ``_plan_one``): ``(qips, qerr)``, both
    (m, cap).

    ``qips[u, j]`` is the dequantized inner product of user row u with
    staged row j; ``qerr[u, j]`` its sound error radius — the same
    ``0.5 * sqrt(d) * slack * scale * ||u||`` Cauchy-Schwarz ball the
    forward merge (``merge_delta_topk``) puts around a query's dequantized
    IP, with the user vector in the query role. Dead slots (scale 0) get
    qips = qerr = 0 and are masked by the caller's ``delta_mask`` anyway.
    Computed once per dispatch by every driver (the full GEMM is the
    identical expression in the per-query and batched paths, keeping their
    screen decisions bitwise consistent).
    """
    radius = 0.5 * float(users.shape[-1]) ** 0.5 * _QERR_SLACK
    qips = (users @ d_qitems.astype(jnp.float32).T) * d_qscale[None, :]
    qerr = radius * d_qscale[None, :] * \
        jnp.linalg.norm(users, axis=-1, keepdims=True)
    return qips, qerr


@functools.partial(jax.jit, static_argnames=("k", "n_cand", "scan"))
def kmips_topk(index: SAALSHIndex, queries: jnp.ndarray, k: int,
               *, n_cand: int = 64, scan: str = "sketch"):
    """Approximate kMIPS (Algorithm 2) for a batch of query/user vectors.

    queries (Q, d) -- need not be unit (the bound uses ||q||).
    Returns (vals (Q, k) descending, ids (Q, k) original item rows,
    tiles_visited ()). Early-terminates when the current kth best phi
    dominates the Cauchy-Schwarz bound mu_tile * ||q|| for every query.
    """
    n_tiles = index.tile_max_norm.shape[0]
    qn = jnp.linalg.norm(queries, axis=-1)                # (Q,)
    n_cand_eff = index.tile if scan == "exact" else n_cand
    ucodes = user_codes(index, queries) if scan == "sketch" else \
        jnp.zeros((queries.shape[0], index.codes.shape[1]), jnp.uint32)

    nq = queries.shape[0]
    vals0 = jnp.full((nq, k), _NEG, jnp.float32)
    ids0 = jnp.full((nq, k), -1, jnp.int32)

    def cond(state):
        t, vals, _ = state
        phi = vals[:, -1]                                 # kth best so far
        mu = index.tile_max_norm[jnp.minimum(t, n_tiles - 1)] * qn
        return (t < n_tiles) & jnp.any(phi < mu)

    def body(state):
        t, vals, ids = state
        tile = index.tile
        ips, valid, local = _tile_candidates(index, ucodes, queries, t,
                                             n_cand=n_cand_eff, scan=scan)
        ips = jnp.where(valid, ips, _NEG)
        global_ids = jnp.take(
            index.item_ids, t * tile + local, axis=0)     # (Q, c)
        merged_v = jnp.concatenate([vals, ips], axis=-1)
        merged_i = jnp.concatenate([ids, global_ids], axis=-1)
        best_v, pos = jax.lax.top_k(merged_v, k)
        best_i = jnp.take_along_axis(merged_i, pos, axis=-1)
        return t + 1, best_v, best_i

    t_fin, vals, ids = jax.lax.while_loop(cond, body, (jnp.asarray(0, jnp.int32),
                                                       vals0, ids0))
    return vals, ids, t_fin
