"""End-to-end behaviour tests: the SAH engine against the exact oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exact, metrics, sah
from repro.data import synthetic

EPS = 1e-5


@pytest.fixture(scope="module")
def workload():
    key = jax.random.PRNGKey(11)
    ki, kq, kb = jax.random.split(key, 3)
    items, users = synthetic.recommendation_data(ki, 2048, 4096, 48)
    norms = jnp.linalg.norm(items, axis=-1)
    order = jnp.argsort(-norms)
    queries = items[order[jax.random.choice(kq, 400, (6,), replace=False)]]
    uu = users / jnp.linalg.norm(users, axis=-1, keepdims=True)
    idx = sah.build(items, users, kb, k_max=50, n_bits=128, tile=256,
                    leaf_size=32)
    return items, users, uu, queries, idx


@pytest.mark.parametrize("k", [1, 10, 50])
def test_exact_scan_matches_oracle(workload, k):
    """scan='exact' is Simpfer's linear scan: must reproduce the oracle."""
    items, users, uu, queries, idx = workload
    truth = exact.rkmips_batch_chunked(items, uu, queries, k, tie_eps=EPS)
    pred, _ = sah.rkmips_batch(idx, queries, k, scan="exact", tie_eps=EPS)
    po = sah.predictions_to_original(idx, pred, users.shape[0])
    np.testing.assert_array_equal(np.asarray(po), np.asarray(truth))


@pytest.mark.parametrize("k", [1, 10])
def test_sketch_scan_f1(workload, k):
    """SA-ALSH sketch scan: approximate, F1 must stay high (paper: >0.9)."""
    items, users, uu, queries, idx = workload
    truth = exact.rkmips_batch_chunked(items, uu, queries, k, tie_eps=EPS)
    pred, _ = sah.rkmips_batch(idx, queries, k, scan="sketch", n_cand=64,
                               tie_eps=EPS)
    po = sah.predictions_to_original(idx, pred, users.shape[0])
    f1 = float(jnp.mean(metrics.f1_score(po, truth)))
    assert f1 > 0.9, f1


def test_sketch_error_is_one_sided(workload):
    """Sketch candidate misses can only under-count beating items, which can
    only flip a correct 'no' into a false 'yes' -- never the reverse. So the
    sketch prediction set must contain every true positive."""
    items, users, uu, queries, idx = workload
    k = 10
    truth = exact.rkmips_batch_chunked(items, uu, queries, k, tie_eps=EPS)
    pred, _ = sah.rkmips_batch(idx, queries, k, scan="sketch", n_cand=64,
                               tie_eps=EPS)
    po = sah.predictions_to_original(idx, pred, users.shape[0])
    assert bool(jnp.all(~truth | po))


def test_batch_matches_single(workload):
    items, users, uu, queries, idx = workload
    k = 10
    batch_pred, _ = sah.rkmips_batch(idx, queries, k, scan="exact",
                                     tie_eps=EPS)
    for i in range(2):
        single, _ = sah.rkmips(idx, queries[i], k, scan="exact", tie_eps=EPS)
        np.testing.assert_array_equal(np.asarray(single),
                                      np.asarray(batch_pred[i]))


def test_query_stats_consistent(workload):
    items, users, uu, queries, idx = workload
    pred, stats = sah.rkmips_batch(idx, queries, 10, scan="exact",
                                   tie_eps=EPS)
    m_real = int(jnp.sum(idx.user_mask))
    assert m_real == users.shape[0]
    s = jax.tree.map(np.asarray, stats)
    assert (s.blocks_alive <= idx.n_blocks).all()
    assert (s.n_scan <= s.users_alive).all()
    assert (s.n_yes_norm + s.n_no_lb <= m_real).all()
