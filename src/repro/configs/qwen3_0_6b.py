"""qwen3-0.6b: 28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936,
qk_norm. [hf:Qwen/Qwen3-0.6B family; hf]"""

import jax.numpy as jnp

from repro.configs import base
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="qwen3-0.6b", n_layers=28, d_model=1024, n_heads=16,
        n_kv_heads=8, d_head=128, d_ff=3072, vocab=151936, qk_norm=True,
        rope_theta=1000000.0, dtype=jnp.bfloat16)


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="qwen3-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_head=32, d_ff=256, vocab=512, qk_norm=True,
        dtype=jnp.float32, max_seq=64, attn_chunk=32)


base.register(base.ArchSpec(
    arch_id="qwen3-0.6b", family="lm", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=base.LM_SHAPES,
    tp_heads=True, pure_dp_train=False, source="hf:Qwen/Qwen3-8B",
    notes="small dense: trains pure-DP on the single-pod mesh (DESIGN SS5)"))
