"""Graph Attention Network (GAT, Velickovic et al. 2018) via segment ops.

JAX has no sparse SpMM beyond BCOO, so message passing is built from
first principles (this IS part of the system, per the task spec):
  * SDDMM (edge scores):  e_ij = LeakyReLU(a_src . h_i + a_dst . h_j)
  * edge softmax:         segment_max (stability) + segment_sum over dst
  * SpMM (aggregate):     segment_sum of alpha_ij * h_i over dst

Graphs are edge lists (src, dst) int32 with a validity mask so shapes stay
static (padded edges point at node 0 with mask=False). Batched small graphs
(the `molecule` shape) are block-diagonal in the same representation.

Distribution (full-graph shapes): edges sharded over every mesh axis via
shard_map; each shard computes partial segment reductions over its edge
range, combined with pmax (softmax max) and psum (sums). Node features /
parameters are replicated -- see DESIGN.md SS5.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist.policy import NO_SHARDING, ShardingPolicy

_NEG = -1e30


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    d_in: int = 1433
    n_classes: int = 7
    negative_slope: float = 0.2
    dtype: object = jnp.float32
    agg_mode: str = "allreduce"   # "allreduce" | "dst_partitioned"
    # dst_partitioned (SSPerf variant): edges are pre-partitioned by
    # destination-node owner (a data-loader guarantee), so every segment
    # reduction is shard-local and the only collective is ONE all-gather of
    # the (N/P, H, D) output slice per layer -- replacing pmax + two
    # all-reduces over (N, H[, D]) of the baseline (~3-4x fewer wire bytes,
    # and no pmax in the backward).


def init_params(key: jax.Array, cfg: GATConfig) -> dict:
    layers = []
    d_in = cfg.d_in
    for li in range(cfg.n_layers):
        k1, k2, k3, key = jax.random.split(key, 4)
        last = li == cfg.n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = cfg.n_classes if last else cfg.d_hidden
        layers.append({
            "w": (jax.random.normal(k1, (d_in, heads, d_out))
                  * d_in ** -0.5).astype(cfg.dtype),
            "a_src": (jax.random.normal(k2, (heads, d_out))
                      * d_out ** -0.5).astype(cfg.dtype),
            "a_dst": (jax.random.normal(k3, (heads, d_out))
                      * d_out ** -0.5).astype(cfg.dtype),
        })
        d_in = heads * d_out if not last else d_out
    return {"layers": layers}


def _edge_scores(h, src, dst, emask, p, slope):
    """h (N,H,D) projected features -> (scores (E,H), h_src gathered later)."""
    s_src = jnp.einsum("nhd,hd->nh", h, p["a_src"])
    s_dst = jnp.einsum("nhd,hd->nh", h, p["a_dst"])
    e = s_src[src] + s_dst[dst]                      # (E, H)
    e = jax.nn.leaky_relu(e, slope)
    return jnp.where(emask[:, None], e, _NEG)


def gat_layer(x, src, dst, emask, p, cfg: GATConfig,
              policy: ShardingPolicy = NO_SHARDING, *, last: bool):
    """x (N, d_in) -> (N, H*D) (or (N, n_classes) for the last layer)."""
    n = x.shape[0]
    h = jnp.einsum("ni,ihd->nhd", x, p["w"])         # (N, H, D)

    def local(src_l, dst_l, emask_l):
        e = _edge_scores(h, src_l, dst_l, emask_l, p, cfg.negative_slope)
        # max-subtraction is numerical stabilization only: its gradient
        # contribution cancels exactly, and pmax has no JVP rule -- so the
        # stop_gradient must sit *before* pmax (tangents never reach it).
        part_max = jax.lax.stop_gradient(
            jax.ops.segment_max(e, dst_l, num_segments=n))        # (N, H)
        if policy.mesh is not None:
            gmax = jax.lax.pmax(part_max, tuple(policy.mesh.axis_names))
        else:
            gmax = part_max
        w = jnp.exp(e - gmax[dst_l]) * emask_l[:, None]           # (E, H)
        den = jax.ops.segment_sum(w, dst_l, num_segments=n)       # (N, H)
        num = jax.ops.segment_sum(w[:, :, None] * h[src_l], dst_l,
                                  num_segments=n)                 # (N, H, D)
        if policy.mesh is not None:
            den = jax.lax.psum(den, tuple(policy.mesh.axis_names))
            num = jax.lax.psum(num, tuple(policy.mesh.axis_names))
        return num, den

    def local_dst_part(src_l, dst_l, emask_l):
        # edges arrive pre-partitioned by dst owner: all reductions local.
        all_axes = tuple(policy.mesh.axis_names)
        n_dev = np.prod([policy.mesh.shape[a] for a in all_axes])
        n_local = n // int(n_dev)
        rank = jax.lax.axis_index(all_axes)
        rel = jnp.clip(dst_l - rank * n_local, 0, n_local - 1)
        e = _edge_scores(h, src_l, dst_l, emask_l, p, cfg.negative_slope)
        pm = jax.lax.stop_gradient(
            jax.ops.segment_max(e, rel, num_segments=n_local))
        w = jnp.exp(e - pm[rel]) * emask_l[:, None]
        den_l = jax.ops.segment_sum(w, rel, num_segments=n_local)
        num_l = jax.ops.segment_sum(w[:, :, None] * h[src_l], rel,
                                    num_segments=n_local)
        out_l = num_l / jnp.maximum(den_l, 1e-9)[:, :, None]
        return jax.lax.all_gather(out_l, all_axes, tiled=True)   # (N, H, D)

    if policy.mesh is None:
        num, den = local(src, dst, emask)
        out = num / jnp.maximum(den, 1e-9)[:, :, None]   # (N, H, D)
    elif cfg.agg_mode == "dst_partitioned":
        all_axes = tuple(policy.mesh.axis_names)
        out = jax.shard_map(
            local_dst_part, mesh=policy.mesh,
            in_specs=(P(all_axes), P(all_axes), P(all_axes)),
            out_specs=P(), check_vma=False)(src, dst, emask)
    else:
        all_axes = tuple(policy.mesh.axis_names)
        num, den = jax.shard_map(
            local, mesh=policy.mesh,
            in_specs=(P(all_axes), P(all_axes), P(all_axes)),
            out_specs=(P(), P()),
            check_vma=False)(src, dst, emask)
        out = num / jnp.maximum(den, 1e-9)[:, :, None]   # (N, H, D)
    if last:
        return jnp.mean(out, axis=1)                 # average heads
    return jax.nn.elu(out.reshape(n, -1))            # concat heads


def forward(params, graph: dict, cfg: GATConfig,
            policy: ShardingPolicy = NO_SHARDING) -> jnp.ndarray:
    """graph = {x (N,F), src (E,), dst (E,), edge_mask (E,)} -> logits (N, C)."""
    x = graph["x"]
    for li, p in enumerate(params["layers"]):
        x = gat_layer(x, graph["src"], graph["dst"], graph["edge_mask"], p,
                      cfg, policy, last=(li == cfg.n_layers - 1))
    return x


def loss_fn(params, graph: dict, cfg: GATConfig,
            policy: ShardingPolicy = NO_SHARDING) -> jnp.ndarray:
    """Cross-entropy loss.

    Node-level: graph holds labels (N,) int32 and label_mask (N,) bool.
    Graph-level (batched small graphs): graph additionally holds
    graph_id (N,) int32 and n_graphs labels; node logits are segment-mean
    pooled per graph before the softmax.
    """
    logits = forward(params, graph, cfg, policy)
    if "graph_id" in graph:
        n_graphs = graph["graph_labels"].shape[0]
        ones = jnp.ones((logits.shape[0],), jnp.float32)
        counts = jax.ops.segment_sum(ones, graph["graph_id"],
                                     num_segments=n_graphs)
        pooled = jax.ops.segment_sum(logits, graph["graph_id"],
                                     num_segments=n_graphs)
        logits = pooled / jnp.maximum(counts, 1.0)[:, None]
        labels, w = graph["graph_labels"], jnp.ones((n_graphs,), jnp.float32)
    else:
        labels = graph["labels"]
        w = graph["label_mask"].astype(jnp.float32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
