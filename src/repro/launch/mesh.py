"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state -- the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for subprocess tests (host platform devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def dp_size(mesh) -> int:
    size = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            size *= mesh.shape[a]
    return size
