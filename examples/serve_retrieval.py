"""End-to-end serving driver: two-tower retrieval with SAH-indexed candidates.

    PYTHONPATH=src python examples/serve_retrieval.py --steps 30

1. trains the (smoke-scale) two-tower model on synthetic interactions
   (in-batch sampled softmax);
2. embeds the item corpus with the item tower, builds the SAH candidate
   index offline (SAT + SRP codes);
3. serves retrieval requests **online through the engine's serving
   subsystem** (repro.engine.serving.RetrievalServer, DESIGN.md SS8):
   requests arrive one at a time, are micro-batched into fixed-size
   dispatches of the sharded sketch scan, and compared against the exact
   fused ip_topk for recall@k + QPS.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import RkMIPSEngine, get_config
from repro.configs import base as cfg_base
from repro.core import metrics
from repro.kernels import ops as kops
from repro.models import recsys as rec_lib
from repro.train import optimizer as opt_lib
from repro.train.trainer import TrainState, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--corpus", type=int, default=16384)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--k", type=int, default=20)
    args = ap.parse_args()

    cfg = cfg_base.get("two-tower-retrieval").make_smoke_config()
    key = jax.random.PRNGKey(0)
    params = rec_lib.init_twotower_params(key, cfg)

    def batch_at(i):
        k = jax.random.fold_in(key, i)
        uf = jnp.stack([jax.random.randint(jax.random.fold_in(k, j),
                                           (args.batch,), 0, v)
                        for j, v in enumerate(cfg.user_embedding.vocab_sizes)
                        ], -1)
        itf = jnp.stack([jax.random.randint(jax.random.fold_in(k, 7 + j),
                                            (args.batch,), 0, v)
                         for j, v in
                         enumerate(cfg.item_embedding.vocab_sizes)], -1)
        return {"user_feats": uf, "item_feats": itf,
                "log_q": jnp.zeros((args.batch,))}

    opt = opt_lib.chain(opt_lib.clip_by_global_norm(1.0),
                        opt_lib.adamw(1e-3))
    step = jax.jit(make_train_step(
        lambda p, b: rec_lib.twotower_loss(p, b, cfg), opt))
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    t0 = time.time()
    for i in range(args.steps):
        state, m = step(state, batch_at(i))
    print(f"trained {args.steps} steps in {time.time()-t0:.1f}s, "
          f"final loss {float(m['loss']):.3f}")

    # --- offline: embed corpus + build SAH index -------------------------
    kc = jax.random.fold_in(key, 999)
    corpus_feats = jnp.stack(
        [jax.random.randint(jax.random.fold_in(kc, j), (args.corpus,), 0, v)
         for j, v in enumerate(cfg.item_embedding.vocab_sizes)], -1)
    cand_vecs = rec_lib.item_tower(state.params, corpus_feats, cfg)
    eng = RkMIPSEngine(get_config("sah").replace(
        n_bits=256, serve_batch_size=min(16, args.requests)))
    eng.build(cand_vecs, None, jax.random.fold_in(key, 5))
    print(f"SAH candidate index built in {eng.build_seconds:.2f}s "
          f"({int(eng.kmips_index.n_parts)} norm partitions)")

    # --- online: batched requests ---------------------------------------
    kr = jax.random.fold_in(key, 1234)
    req_feats = jnp.stack(
        [jax.random.randint(jax.random.fold_in(kr, j), (args.requests,),
                            0, v)
         for j, v in enumerate(cfg.user_embedding.vocab_sizes)], -1)
    u = rec_lib.user_tower(state.params, req_feats, cfg)

    ev, ei = kops.ip_topk(u, cand_vecs, args.k)          # exact
    jax.block_until_ready(ev)
    t0 = time.time()
    ev, ei = kops.ip_topk(u, cand_vecs, args.k)
    jax.block_until_ready(ev)
    t_exact = time.time() - t0

    # Online serving: requests arrive one at a time; the server accumulates
    # them into fixed-size micro-batches (one compile per batch size) and
    # dispatches the sharded sketch scan (DESIGN.md SS8).
    server = eng.server()
    for i in range(args.requests):                       # warm (compile)
        server.submit(u[i])
    server.flush(args.k, n_cand=64)
    t0 = time.time()
    for i in range(args.requests):
        server.submit(u[i])
    results = server.flush(args.k, n_cand=64)
    jax.block_until_ready(results[-1].values)
    t_sah = time.time() - t0

    sids = jnp.stack([r.ids for r in results])
    rec = float(jnp.mean(metrics.recall_at_k(sids, ei)))
    print(f"\nexact : {args.requests/t_exact:8.0f} QPS")
    print(f"SAH   : {args.requests/t_sah:8.0f} QPS  recall@{args.k}={rec:.3f}"
          f"  (micro-batch {server.batch_size}, "
          f"{server.compile_count} compile)")


if __name__ == "__main__":
    main()
