"""Kernel-level microbenchmarks: jnp reference path timings on CPU (the
Pallas kernels themselves target TPU; interpret-mode timing is meaningless,
so we time the dispatch path the CPU benchmarks actually use, plus report
the bytes-reduction each kernel achieves on TPU by construction).

The ``kernel/fused_scan`` grid times the decide_count hot loop itself
(DESIGN.md SS13) per (k, nq, m): the f32 tile scan is the floor row and the
int8 row carries ``speedup=`` against it — on CPU that compares the lax
mirror of the fused kernel (iterated-argmin selection + int8 gathers)
against the stock ``lax.top_k`` + f32 gather scan, the honest CPU version
of the bandwidth win the Pallas kernel realizes on TPU. Taus sit at a high
quantile so lanes stay undecided across most norm-ordered tiles — the
deep-scan regime ROADMAP names as dominant at large m.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import sa_alsh
from repro.kernels import ops


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _fused_scan_rows(m, d, ks_nqs, reps=2):
    key = jax.random.PRNGKey(7)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    items = jax.random.normal(k1, (m, d)) * \
        jax.random.uniform(k2, (m, 1), minval=0.2, maxval=1.5)
    idx = sa_alsh.build_index(items, k3, tile=512, n_bits=256)
    nq_max = max(nq for _, nq in ks_nqs)
    users = jax.random.normal(k4, (nq_max, d))
    users = users / jnp.linalg.norm(users, axis=-1, keepdims=True)
    # few enough beaters that large-k lanes must walk most tiles
    taus = jnp.quantile(users @ items.T, 0.999, axis=-1)

    rows = []
    for k, nq in ks_nqs:
        u, t = users[:nq], taus[:nq]
        init = jnp.zeros(nq, jnp.int32)
        active = jnp.ones(nq, bool)
        dts = {}
        for prec in ("f32", "int8"):
            fn = functools.partial(sa_alsh.decide_count, idx, u, t, init,
                                   active, k, n_cand=64, scan="sketch",
                                   scan_precision=prec)
            dts[prec] = _time(fn, reps=reps)
        _, tiles = sa_alsh.decide_count(idx, u, t, init, active, k,
                                        n_cand=64, scan="sketch")
        base = f"k{k}/nq{nq}/m{m}"
        rows.append(common.fmt_row(
            f"kernel/fused_scan/f32/{base}", dts["f32"] * 1e6,
            f"tiles={int(tiles)};floor=1.00"))
        rows.append(common.fmt_row(
            f"kernel/fused_scan/int8/{base}", dts["int8"] * 1e6,
            f"tiles={int(tiles)};"
            f"speedup={dts['f32'] / dts['int8']:.2f}x_vs_f32"))
    return rows


def run(n=65536, d=128, n_bits=256, q=64, fused_m=65536,
        fused_grid=((10, 64), (50, 256))):
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (n, d))
    proj = jax.random.normal(k2, (d, n_bits))
    queries = jax.random.normal(k3, (q, d))

    rows = []
    dt = _time(ops.srp_hash, x, proj)
    rows.append(common.fmt_row(
        "kernel/srp_hash", dt * 1e6,
        f"n={n};bits={n_bits};tpu_hbm_out_bytes=1/{8 * 4}x_of_signs"))

    codes = ops.srp_hash(x, proj)
    qcodes = ops.srp_hash(queries, proj)
    dt = _time(ops.hamming_scores, qcodes, codes)
    ip_bytes = n * d * 4
    code_bytes = n * (n_bits // 8)
    rows.append(common.fmt_row(
        "kernel/hamming_scores", dt * 1e6,
        f"q={q};n={n};bytes_vs_exact={code_bytes / ip_bytes:.3f}"))

    dt = _time(lambda a, b: ops.ip_topk(a, b, 100), queries, x)
    rows.append(common.fmt_row("kernel/ip_topk", dt * 1e6, f"k=100;n={n}"))

    # fused_m stays at the paper's large-m point even at smoke scale: the
    # committed BENCH cells must show the int8 scan's win where it matters
    rows.extend(_fused_scan_rows(fused_m, d, fused_grid))
    return rows
