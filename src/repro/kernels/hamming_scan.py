"""Pallas TPU kernel: all-pairs Hamming distance between bit-packed SRP codes.

This is the hot inner loop of SA-ALSH on TPU: for a chunk of users (queries)
and a norm-ordered tile of items, score every pair by popcount(xor(codes)).
Compared to the exact float scan it moves 32x fewer bytes per item
(B bits vs d floats) and runs entirely on the VPU.

Tiling: grid (q_tiles, n_tiles). Each program instance loads a
(block_q, W) query-code tile and a (block_n, W) item-code tile into VMEM and
writes a (block_q, block_n) int32 distance tile. The (block_q, block_n, W)
XOR intermediate lives only in VREGs/VMEM.

VMEM budget at defaults (block_q=128, block_n=512, W<=8):
  in: 128*8*4 + 512*8*4 = 20 KB, intermediate 128*512*8*4 = 2 MB, out 256 KB
  -- comfortably inside the ~16 MB v5e VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hamming_kernel(q_ref, n_ref, out_ref):
    q = q_ref[...]                       # (bq, W) uint32
    n = n_ref[...]                       # (bn, W) uint32
    x = jnp.bitwise_xor(q[:, None, :], n[None, :, :])   # (bq, bn, W)
    pc = jax.lax.population_count(x)
    out_ref[...] = jnp.sum(pc, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_q", "block_n", "interpret"))
def hamming_scores(query_codes: jnp.ndarray, item_codes: jnp.ndarray,
                   *, block_q: int = 128, block_n: int = 512,
                   interpret: bool = False) -> jnp.ndarray:
    """query_codes (q, W) uint32, item_codes (n, W) uint32 -> (q, n) int32.

    q and n must be multiples of block_q / block_n (callers pad; the core
    library always presents tile-aligned code arrays).
    """
    q, w = query_codes.shape
    n, w2 = item_codes.shape
    assert w == w2, (w, w2)
    assert q % block_q == 0 and n % block_n == 0, (q, n, block_q, block_n)
    grid = (q // block_q, n // block_n)
    return pl.pallas_call(
        _hamming_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, w), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, w), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, n), jnp.int32),
        interpret=interpret,
    )(query_codes, item_codes)
