"""IndexArtifact: the build/attach lifecycle of SAH indexes (DESIGN.md SS10).

The paper's index is an offline artifact; before this module it only existed
as private state inside a live ``RkMIPSEngine`` — impossible to save, ship
to a different mesh, share between engines and servers, or update when the
item corpus changes. ``IndexArtifact`` is that artifact made first-class:

  * a **value type** bundling the SAH user index, the (lazily built) kMIPS
    index, the build key, the source arrays, and a content fingerprint —
    mutating operations (``insert_items`` / ``delete_items`` / ``compact``)
    return a *new* artifact version and never touch the one an engine or
    server is currently attached to;
  * **persistence**: ``save(dir)`` / ``load(dir)`` ride the SS6 elastic
    checkpoint machinery (``train/checkpoint.py``: host-gathered npz plus a
    fsynced, fingerprint-bearing manifest). Artifacts are stored in host
    layout, mesh-agnostic; ``RkMIPSEngine.attach`` lays the arrays out for
    whatever ``ShardingPolicy`` the attaching engine carries, so an index
    built on one mesh restores onto any other (or onto one device);
  * **streaming corpus deltas**: ``insert_items`` stages new rows in a
    fixed-capacity, exactly-scanned delta buffer (masked, static shapes —
    the engine pays one extra compile ever, not one per mutation);
    ``delete_items`` retires base-corpus or staged rows. ``compact()``
    merges everything into fresh norm-ordered partitions by an explicit
    from-scratch rebuild on the *effective corpus* (surviving base rows in
    original order, then surviving staged rows in insertion order).

Delta-view invariants (what keeps pre-compact answers honest):

  the attached engine queries a *view* of the base index whose shapes are
  unchanged — deleted rest-items are masked out of the SA-ALSH scan,
  ``user_lb``/``block_lb`` are recomputed over P' minus its deleted members
  (still valid lower bounds: deletions shrink them, insertions only help),
  and ``top_norms`` is the exact top-norm vector of the *mutated* corpus
  (so the "yes by norm" shortcut can never fire against a stale, too-small
  k-th norm after inserts). Staged rows are scanned exactly and added to
  each lane's count. Every shortcut stays conservative and the counting
  fallback is exact, so for **exact-scan configs** pre-compact predictions
  are bitwise equal to a from-scratch build on the mutated corpus; sketch
  configs regain their (layout-sensitive) approximation pattern at
  ``compact()``, which is bitwise a from-scratch build by construction.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sa_alsh as _alsh
from repro.core import sah as _sah
from repro.core import simpfer as _simpfer
from repro.dist.policy import NO_SHARDING, ShardingPolicy
from repro.engine import build as _build
from repro.engine.config import EngineConfig, get_config
from repro.train import checkpoint as _ckpt

# fold_in tag deriving the kMIPS index key from the build key; shared by
# every kMIPS surface (engine, servers, serving_codes) so they all rank
# with one set of SRP codes.
KMIPS_KEY_TAG = 0x5A11

_FORMAT = 1
_KIND = "sah-index-artifact"


def _array_bytes(x) -> bytes:
    a = np.asarray(jax.device_get(x))
    return (str(a.dtype).encode() + str(a.shape).encode()
            + np.ascontiguousarray(a).tobytes())


def corpus_fingerprint(items: jnp.ndarray, key: jax.Array) -> str:
    """Content hash of a raw serving corpus + its index key.

    The ``ServingCache`` key prefix for servers built outside the artifact
    lifecycle; artifact-attached surfaces use ``IndexArtifact.fingerprint``
    (which additionally covers users, config, and pending deltas)."""
    h = hashlib.sha256(b"repro-corpus-v1")
    h.update(_array_bytes(items))
    h.update(_array_bytes(key))
    return h.hexdigest()


def _validate_corpus(items, users) -> None:
    """Satellite: fail build-time input mistakes up front with a clear
    ValueError instead of a shape error deep inside ``sah.build``."""
    if getattr(items, "ndim", None) != 2:
        raise ValueError(f"items must be a 2-D (n, d) array, got shape "
                         f"{getattr(items, 'shape', None)}")
    if items.shape[0] < 1 or items.shape[1] < 1:
        raise ValueError(f"items must be non-empty in both axes, got shape "
                         f"{items.shape}")
    if not jnp.issubdtype(items.dtype, jnp.floating):
        raise ValueError(f"items must have a floating dtype, got "
                         f"{items.dtype}")
    if users is None:
        return
    if getattr(users, "ndim", None) != 2:
        raise ValueError(f"users must be a 2-D (m, d) array or None, got "
                         f"shape {getattr(users, 'shape', None)}")
    if users.shape[0] < 1:
        raise ValueError("users must be non-empty (or None for a "
                         "kMIPS-only build)")
    if not jnp.issubdtype(users.dtype, jnp.floating):
        raise ValueError(f"users must have a floating dtype, got "
                         f"{users.dtype}")
    if users.shape[1] != items.shape[1]:
        raise ValueError(f"users dimensionality ({users.shape[1]}) != items "
                         f"dimensionality ({items.shape[1]})")


def _flatten_named(prefix: str, nt, out: dict) -> None:
    for name, v in zip(type(nt)._fields, nt):
        if hasattr(v, "_fields"):
            _flatten_named(f"{prefix}{name}/", v, out)
        else:
            out[f"{prefix}{name}"] = v


def _unflatten_sah(tree: dict) -> _sah.SAHIndex:
    alsh = _alsh.SAALSHIndex(**{f: tree[f"index/alsh/{f}"]
                                for f in _alsh.SAALSHIndex._fields})
    rest = {f: tree[f"index/{f}"] for f in _sah.SAHIndex._fields
            if f != "alsh"}
    return _sah.SAHIndex(alsh=alsh, **rest)


def _unflatten_kmips(tree: dict) -> _alsh.SAALSHIndex:
    return _alsh.SAALSHIndex(**{f: tree[f"kmips/{f}"]
                                for f in _alsh.SAALSHIndex._fields})


class IndexArtifact:
    """One immutable version of a built SAH index + its corpus deltas.

    Construct with ``IndexArtifact.build`` (or ``load``); the raw
    constructor wires already-built pieces together. Treat instances as
    values: every mutating operation returns a new artifact, and
    ``fingerprint`` identifies a version's full content (corpus, users,
    key, config, staged deltas) — it is what ``ServingCache`` keys on.
    """

    def __init__(self, *, config: EngineConfig, key: jax.Array,
                 items: jnp.ndarray, users: jnp.ndarray | None,
                 index: _sah.SAHIndex | None,
                 kmips_index: _alsh.SAALSHIndex | None,
                 deleted: jnp.ndarray, delta_items: jnp.ndarray,
                 delta_mask: jnp.ndarray, delta_used: int):
        self.config = config
        self.key = key
        self.items = items                  # (n_base, d) corpus at build
        self.users = users                  # (m, d) or None (kMIPS-only)
        self.index = index                  # SAHIndex or None
        self.deleted = deleted              # (n_base,) bool
        self.delta_items = delta_items      # (capacity, d) staged rows
        self.delta_mask = delta_mask        # (capacity,) bool live rows
        self.delta_used = int(delta_used)   # slots consumed (append-only)
        # Staged rows quantized at insert (every insert evolves a new
        # artifact through here). Per-row scales -- partitions are a
        # compacted-index notion; dead slots quantize to zeros/scale 0.
        # Persisted with the version and consumed by both int8 delta
        # screens: forward serving (``kmips_delta_quantized`` ->
        # ``sa_alsh.merge_delta_topk``) and the reverse plan's staged-row
        # count (``sa_alsh.delta_screen_tables`` -> ``sah._plan_one``),
        # closing the DESIGN.md SS13 remainder.
        self.delta_qitems, self.delta_qscale = \
            _alsh.quantize_rows(delta_items)
        # Transient diagnostics of the build that made this version (a
        # BuildTimings, engine/build.py), None when wired from pieces or
        # loaded from disk; never part of the fingerprint or the manifest.
        self.build_timings = None
        self._kmips = kmips_index           # lazy memo (derived content)
        self._kmips_view = None
        self._base_fp: str | None = None    # hash of the built base content
        self._fingerprint: str | None = None
        self._users_unit = None

    # -- construction ------------------------------------------------------

    @classmethod
    def build(cls, items: jnp.ndarray, users: jnp.ndarray | None,
              key: jax.Array, *, config: EngineConfig | str = "sah",
              delta_capacity: int | None = None,
              policy: ShardingPolicy = NO_SHARDING) -> "IndexArtifact":
        """Build a fresh artifact through the staged build pipeline
        (engine/build.py) — bitwise the legacy ``sah.build`` result, so an
        engine built ``from_artifact`` is bit-for-bit the ``build()``
        engine, and ``policy`` (with ``config.build_sharding``) only
        changes *where* the row-parallel stages run, never the artifact's
        content or fingerprint (DESIGN.md SS11). The per-stage wall-time
        breakdown lands on ``self.build_timings``.

        ``users=None`` builds a kMIPS-only artifact (the SA-ALSH index over
        the full corpus is built eagerly; with users it stays lazy).
        ``delta_capacity`` (default ``config.delta_capacity``) fixes the
        staged-insert buffer size — static shapes, so attached engines
        compile the delta pipeline at most once regardless of churn.
        """
        if isinstance(config, str):
            config = get_config(config)
        _validate_corpus(items, users)
        _build.validate_build_knobs(config)
        cap = config.delta_capacity if delta_capacity is None \
            else int(delta_capacity)
        if cap < 1:
            raise ValueError(f"delta_capacity must be >= 1, got {cap}")
        index = kmips = timings = None
        if users is None:
            kmips = _alsh.build_index(
                items, jax.random.fold_in(key, KMIPS_KEY_TAG),
                **config.kmips_build_kwargs(items.shape[0]))
        else:
            index, timings = _build.build_sah_index(items, users, key,
                                                    config=config,
                                                    policy=policy)
        n, d = items.shape
        art = cls(config=config, key=key, items=items, users=users,
                  index=index, kmips_index=kmips,
                  deleted=jnp.zeros((n,), bool),
                  delta_items=jnp.zeros((cap, d), items.dtype),
                  delta_mask=jnp.zeros((cap,), bool), delta_used=0)
        art.build_timings = timings
        return art

    def _evolve(self, **overrides) -> "IndexArtifact":
        kw = dict(config=self.config, key=self.key, items=self.items,
                  users=self.users, index=self.index,
                  kmips_index=self._kmips, deleted=self.deleted,
                  delta_items=self.delta_items, delta_mask=self.delta_mask,
                  delta_used=self.delta_used)
        kw.update(overrides)
        child = IndexArtifact(**kw)
        # delta mutations never touch the built base content: the child
        # inherits the (expensive, O(n*d)) base hash and the normalized
        # users, and only re-hashes its own delta state — streaming
        # hot-swaps stay O(cap*d)
        child._base_fp = self._base_fp
        child._users_unit = self._users_unit
        child.build_timings = self.build_timings
        return child

    # -- identity ----------------------------------------------------------

    @property
    def delta_capacity(self) -> int:
        return self.delta_items.shape[0]

    @property
    def n_base(self) -> int:
        """Rows of the base (last-compacted) corpus."""
        return self.items.shape[0]

    @property
    def n_users(self) -> int | None:
        return None if self.users is None else self.users.shape[0]

    @property
    def n_items(self) -> int:
        """Rows of the *effective* (mutated) corpus."""
        return (self.n_base - int(np.asarray(self.deleted).sum())
                + int(np.asarray(self.delta_mask).sum()))

    @property
    def has_pending(self) -> bool:
        """Any staged change (delete or live insert) not yet compacted."""
        return bool(np.asarray(self.deleted).any()) or \
            bool(np.asarray(self.delta_mask).any())

    @property
    def kmips_index(self) -> _alsh.SAALSHIndex | None:
        """The base-corpus SA-ALSH index if already built (no side effect)."""
        return self._kmips

    @property
    def fingerprint(self) -> str:
        """Content hash of this artifact version (lazily computed).

        Covers the base corpus, users, build key, full config, and every
        staged delta — two artifacts with equal fingerprints serve
        identical answers, and `ServingCache` keys built serving state on
        it so every engine/server surface sharing a recipe shares one set
        of SRP codes (and distinct corpus *versions* can never collide).

        The hash is state-based, not path-based (the same base content +
        the same staged state always hashes the same), and two-level: the
        O(n*d) base hash is computed once per built corpus and inherited
        across delta mutations, so per-version fingerprints cost only the
        delta state.
        """
        if self._fingerprint is None:
            if self._base_fp is None:
                b = hashlib.sha256(f"{_KIND}-v{_FORMAT}".encode())
                # build_sharding, scan_precision and scan_budget are
                # execution-only: the built content (DESIGN.md SS11) is
                # bitwise identical either way (a budget changes answers
                # but flags them per ticket, never the build), so a
                # sharded build, an int8-scanning config or a budgeted
                # tenant must fingerprint-match the defaults
                cfg = self.config.replace(build_sharding="auto",
                                          scan_precision="f32",
                                          scan_budget=0)
                b.update(repr(dataclasses.astuple(cfg)).encode())
                b.update(_array_bytes(self.key))
                b.update(_array_bytes(self.items))
                b.update(b"users" if self.users is None
                         else _array_bytes(self.users))
                self._base_fp = b.hexdigest()
            h = hashlib.sha256(self._base_fp.encode())
            h.update(_array_bytes(self.deleted))
            h.update(_array_bytes(self.delta_items))
            h.update(_array_bytes(self.delta_mask))
            h.update(str(self.delta_used).encode())
            self._fingerprint = h.hexdigest()
        return self._fingerprint

    @property
    def base_fingerprint(self) -> str:
        """Content hash of the built *base* (corpus, users, key, recipe)
        only — shared by every delta-descendant of one build, and changed
        only by ``compact()``/``build``. The forward serving cache keys on
        it (engine/serving.py): staged deltas move the overlay, never the
        cached base state, so streaming churn rebinds in O(1)."""
        if self._base_fp is None:
            self.fingerprint  # computes and memoizes _base_fp
        return self._base_fp

    @property
    def manifest(self) -> dict:
        """The JSON-serializable description ``save`` persists (and
        ``load`` verifies the restored content against)."""
        return {
            "kind": _KIND,
            "format": _FORMAT,
            "fingerprint": self.fingerprint,
            "config": dataclasses.asdict(self.config),
            "n_base": self.n_base,
            "n_users": self.n_users,
            "n_items": self.n_items,
            "delta_capacity": self.delta_capacity,
            "delta_used": self.delta_used,
            "has_index": self.index is not None,
            "has_kmips": self._kmips is not None,
        }

    # -- derived views -----------------------------------------------------

    def users_unit(self) -> jnp.ndarray | None:
        if self.users is None:
            return None
        if self._users_unit is None:
            un = jnp.linalg.norm(self.users, axis=-1, keepdims=True)
            self._users_unit = self.users / jnp.maximum(un, 1e-12)
        return self._users_unit

    def effective_items(self) -> jnp.ndarray:
        """The mutated corpus in compaction order: surviving base rows in
        original order, then surviving staged rows in insertion order."""
        if not self.has_pending:
            return self.items
        keep = np.asarray(~self.deleted)
        live = np.asarray(self.delta_mask)
        return jnp.concatenate([self.items[keep], self.delta_items[live]])

    def effective_ids(self) -> np.ndarray:
        """Artifact-space item id of each ``effective_items()`` row (int32,
        length ``n_items``): surviving base rows keep their base ids,
        staged row ``j`` is ``n_base + j``. The translation every surface
        built from the effective snapshot (e.g. a hot-swapped
        ``RetrievalServer``) applies so its answers agree with
        ``RkMIPSEngine.kmips`` id-for-id."""
        if not self.has_pending:
            return np.arange(self.n_base, dtype=np.int32)
        base = np.where(~np.asarray(self.deleted))[0]
        slots = np.where(np.asarray(self.delta_mask))[0]
        return np.concatenate([base, self.n_base + slots]).astype(np.int32)

    def ensure_kmips_index(self) -> _alsh.SAALSHIndex:
        """The full-base-corpus SA-ALSH index, built lazily and memoized.

        Key derivation (``fold_in(key, KMIPS_KEY_TAG)``) matches the eager
        users=None build, so every surface ranks with identical codes."""
        if self._kmips is None:
            self._kmips = _alsh.build_index(
                self.items, jax.random.fold_in(self.key, KMIPS_KEY_TAG),
                **self.config.kmips_build_kwargs(self.n_base))
        return self._kmips

    def kmips_delta(self):
        """The delta-liveness rule, owned here: ``(delta_items,
        delta_mask)`` when any staged row is live, else ``(None, None)``.
        Every surface that folds the buffer in (the reverse query view,
        the engine's forward merge) reads this one accessor."""
        if bool(np.asarray(self.delta_mask).any()):
            return self.delta_items, self.delta_mask
        return None, None

    def kmips_delta_quantized(self):
        """``kmips_delta`` plus the buffer's persisted int8 twin:
        ``(delta_items, delta_mask, delta_qitems, delta_qscale)`` when any
        staged row is live, else ``(None,) * 4``. The forward delta merge
        reads this so ``scan_precision="int8"`` can screen staged rows
        with the quantized codes stamped at insert
        (``sa_alsh.merge_delta_topk``)."""
        if bool(np.asarray(self.delta_mask).any()):
            return (self.delta_items, self.delta_mask,
                    self.delta_qitems, self.delta_qscale)
        return None, None, None, None

    def kmips_query_view(self) -> _alsh.SAALSHIndex:
        """The kMIPS index with deleted rows masked out of the scan (same
        shapes as the base index: deletions never recompile anything)."""
        if self._kmips_view is None:
            idx = self.ensure_kmips_index()
            if not bool(np.asarray(self.deleted).any()):
                self._kmips_view = idx
            else:
                ids = idx.item_ids
                dead = jnp.where(ids >= 0,
                                 jnp.take(self.deleted, jnp.clip(ids, 0)),
                                 False)
                self._kmips_view = idx._replace(
                    item_mask=idx.item_mask & ~dead)
        return self._kmips_view

    def query_view(self):
        """What an attached engine dispatches reverse queries against:
        ``(SAHIndex view, delta_items | None, delta_mask | None)``.

        Without pending deltas this is the base index itself (identical
        arrays — the zero-churn path costs nothing). With pending deltas
        the view keeps every shape of the base index (one executable
        serves every version) and restores the module-docstring
        invariants: deleted rest-rows leave the scan mask, the Simpfer
        bounds are recomputed over P' minus its deleted members, and
        ``top_norms`` becomes the exact top-norm vector of the mutated
        corpus. Live staged rows ride along as the exactly-scanned delta
        buffer; ``None`` when only deletions are pending, so delete-only
        churn reuses the plain pipeline's executable.
        """
        if self.index is None:
            raise RuntimeError("artifact has no user-side index: built "
                               "with users=None (kMIPS-only)")
        if not self.has_pending:
            return self.index, None, None
        idx = self.index
        if bool(np.asarray(self.deleted).any()):
            del_top = jnp.take(self.deleted, idx.top_ids)
            rest_ids = idx.alsh.item_ids
            del_rest = jnp.where(
                rest_ids >= 0,
                jnp.take(self.deleted, jnp.clip(rest_ids, 0)), False)
            alsh_mask = idx.alsh.item_mask & ~del_rest
            top_alive = jnp.where(del_top, -jnp.inf, idx.top_norms)
            if bool(np.asarray(del_top).any()):
                user_lb = _simpfer.user_lower_bounds(
                    idx.users, idx.top_items, idx.kmax, mask=~del_top)
                block_lb = _simpfer.block_lower_bounds(
                    jnp.where(idx.user_mask[:, None], user_lb, jnp.inf),
                    idx.n_blocks)
                block_lb = jnp.where(jnp.isfinite(block_lb), block_lb,
                                     -jnp.inf)
            else:
                # no P' member retired: the stored bounds are already the
                # recompute's bitwise result — skip the (m, n_top) sweep
                user_lb, block_lb = idx.user_lb, idx.block_lb
        else:
            # insert-only churn: nothing to mask, nothing to re-bound
            alsh_mask = idx.alsh.item_mask
            top_alive = idx.top_norms
            user_lb, block_lb = idx.user_lb, idx.block_lb
        delta_norms = jnp.where(
            self.delta_mask,
            jnp.linalg.norm(self.delta_items, axis=-1), -jnp.inf)
        merged = jnp.concatenate([
            top_alive,
            jnp.where(alsh_mask, idx.alsh.norms, -jnp.inf),
            delta_norms])
        top_norms, _ = jax.lax.top_k(merged, idx.top_norms.shape[0])
        view = idx._replace(alsh=idx.alsh._replace(item_mask=alsh_mask),
                            user_lb=user_lb, block_lb=block_lb,
                            top_norms=top_norms)
        return (view,) + self.kmips_delta()

    # -- streaming corpus deltas -------------------------------------------

    def insert_items(self, rows: jnp.ndarray) -> "IndexArtifact":
        """Stage new corpus rows; returns the new artifact version.

        Rows land in the fixed-capacity delta buffer (slots are consumed
        append-only until ``compact()``), are scanned exactly by every
        attached engine, and get item ids ``n_base + slot``. Raises
        ``ValueError`` when the staged rows would not fit — compact first.
        """
        rows = jnp.asarray(rows)
        if rows.ndim == 1:
            rows = rows[None]
        if rows.ndim != 2 or rows.shape[1] != self.items.shape[1]:
            raise ValueError(f"rows must be (r, {self.items.shape[1]}) to "
                             f"match the corpus, got shape {rows.shape}")
        if not jnp.issubdtype(rows.dtype, jnp.floating):
            raise ValueError(f"rows must have a floating dtype, got "
                             f"{rows.dtype}")
        r = rows.shape[0]
        free = self.delta_capacity - self.delta_used
        if r > free:
            raise ValueError(
                f"delta buffer full: {r} rows do not fit in the "
                f"{free} free of {self.delta_capacity} slots "
                f"({self.delta_used} used); call compact() first")
        sl = slice(self.delta_used, self.delta_used + r)
        return self._evolve(
            delta_items=self.delta_items.at[sl].set(
                rows.astype(self.delta_items.dtype)),
            delta_mask=self.delta_mask.at[sl].set(True),
            delta_used=self.delta_used + r)

    def delete_items(self, ids: Iterable[int]) -> "IndexArtifact":
        """Retire corpus rows by id; returns the new artifact version.

        Ids ``< n_base`` address the base corpus; ids in
        ``[n_base, n_base + delta_used)`` address staged inserts.
        Idempotent per id; out-of-range ids raise ``ValueError``.
        """
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        hi = self.n_base + self.delta_used
        if ids.size and (ids.min() < 0 or ids.max() >= hi):
            raise ValueError(f"item ids must be in [0, {hi}) "
                             f"({self.n_base} base rows + {self.delta_used} "
                             f"staged), got {ids[(ids < 0) | (ids >= hi)]}")
        base = ids[ids < self.n_base]
        slots = ids[ids >= self.n_base] - self.n_base
        return self._evolve(
            deleted=self.deleted.at[base].set(True),
            delta_mask=self.delta_mask.at[slots].set(False))

    def compact(self, *, policy: ShardingPolicy = NO_SHARDING
                ) -> "IndexArtifact":
        """Fold every staged change into a fresh from-scratch build on the
        effective corpus (same users, same key, same config) — bitwise the
        artifact a cold ``build`` would produce on the mutated corpus —
        and reset the delta buffer. Returns self when nothing is staged.

        ``policy``: run the rebuild's row-parallel stages on a mesh
        (engine/build.py) — same artifact bitwise, smaller stop-the-world
        window for hot-swap serving.
        """
        if self.delta_used == 0 and not bool(np.asarray(self.deleted).any()):
            return self
        return IndexArtifact.build(self.effective_items(), self.users,
                                   self.key, config=self.config,
                                   delta_capacity=self.delta_capacity,
                                   policy=policy)

    # -- serving surface ---------------------------------------------------

    def serving_corpus(self) -> tuple[jnp.ndarray, jax.Array, str]:
        """``(effective items, serving key, fingerprint)`` — the mutated
        corpus snapshot plus this version's full-content hash. The key
        derivation matches every other kMIPS surface. Consumers that want
        incremental delta serving bind ``serving_base()`` instead; this
        accessor is for surfaces that need the materialized effective
        corpus (e.g. an offline rebuild of exactly this version)."""
        return (self.effective_items(),
                jax.random.fold_in(self.key, KMIPS_KEY_TAG),
                self.fingerprint)

    def serving_base(self) -> tuple[jnp.ndarray, jax.Array, str]:
        """``(base items, serving key, base fingerprint)`` — what the
        forward serving stack binds its ``ServingCache`` to
        (engine/serving.py). Deltas ride as an incremental overlay
        (deletion mask + exactly-scanned staged rows), so every
        delta-descendant of one build shares one cached state and a
        streaming hot-swap never rebuilds. The key derivation matches
        every other kMIPS surface."""
        return (self.items,
                jax.random.fold_in(self.key, KMIPS_KEY_TAG),
                self.base_fingerprint)

    def serving_codes(self) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Offline sketch build for the serving stack
        (``launch/serve.py::build_candidate_index``).

        Returns ``(codes (n_base, W) uint32, proj_q (d, n_bits) f32)``:
        ``codes[i]`` is the SAT+SRP sketch of base row ``i`` — **input row
        order**, shippable next to the item vectors — and ``proj_q`` the
        query-side projection (first d rows of the shared SRP matrix).
        """
        idx = self.ensure_kmips_index()
        n = self.n_base
        codes = jnp.zeros((n, idx.codes.shape[1]), jnp.uint32)
        codes = codes.at[idx.item_ids].set(idx.codes, mode="drop")
        return codes, idx.proj[:-1]

    # -- persistence (SS6 elastic checkpoints) -----------------------------

    def _flat_arrays(self) -> dict:
        out = {"items": self.items, "key": self.key,
               "deleted": self.deleted, "delta_items": self.delta_items,
               "delta_mask": self.delta_mask,
               "delta_qitems": self.delta_qitems,
               "delta_qscale": self.delta_qscale}
        if self.users is not None:
            out["users"] = self.users
        if self.index is not None:
            _flatten_named("index/", self.index, out)
        if self._kmips is not None:
            _flatten_named("kmips/", self._kmips, out)
        return out

    def save(self, artifact_dir: str, *, step: int = 0,
             keep: int | None = None) -> str:
        """Persist this version under ``artifact_dir`` (atomic: npz +
        fsynced manifest via ``train/checkpoint.py``). Arrays are
        host-gathered, so saving works from any mesh; the stored layout is
        mesh-agnostic and ``RkMIPSEngine.attach`` re-places it under any
        ``ShardingPolicy`` on load. Returns the checkpoint path.

        ``keep=N`` applies the GC/retention policy after a successful
        save: the directory's version history is pruned to the N newest
        steps (``train/checkpoint.py::prune``), with the just-saved step
        always protected — a background compactor streaming versions to
        disk can never GC the artifact it just persisted, whatever its
        step number. ``keep=None`` (default) retains everything.
        """
        if keep is not None and keep < 1:
            raise ValueError(f"keep must be >= 1 (the saved version always "
                             f"survives), got {keep}")
        path = _ckpt.save(artifact_dir, step, self._flat_arrays(),
                          metadata=self.manifest)
        if keep is not None:
            _ckpt.prune(artifact_dir, keep, protect=(step,))
        return path

    @classmethod
    def load(cls, artifact_dir: str, *,
             step: int | None = None) -> "IndexArtifact":
        """Restore the newest (or given) saved version from
        ``artifact_dir``; verifies the recomputed content fingerprint
        against the manifest, so silent corruption cannot load."""
        if step is None:
            step = _ckpt.latest_step(artifact_dir)
            if step is None:
                raise FileNotFoundError(
                    f"no saved index artifact under {artifact_dir!r}")
        manifest = _ckpt.read_manifest(artifact_dir, step)
        meta = manifest["metadata"]
        if meta.get("kind") != _KIND:
            raise ValueError(f"{artifact_dir!r} step {step} is not an index "
                             f"artifact (kind={meta.get('kind')!r})")
        if meta.get("format", 0) > _FORMAT:
            raise ValueError(f"artifact format {meta['format']} is newer "
                             f"than this build supports ({_FORMAT})")
        like = {k: np.empty(v["shape"], np.dtype(v["dtype"]))
                for k, v in manifest["index"].items()}
        tree, _ = _ckpt.restore(artifact_dir, step, like)
        config = EngineConfig(**meta["config"])
        art = cls(
            config=config, key=tree["key"], items=tree["items"],
            users=tree.get("users"),
            index=_unflatten_sah(tree) if meta["has_index"] else None,
            kmips_index=_unflatten_kmips(tree) if meta["has_kmips"]
            else None,
            deleted=tree["deleted"], delta_items=tree["delta_items"],
            delta_mask=tree["delta_mask"], delta_used=meta["delta_used"])
        if art.fingerprint != meta["fingerprint"]:
            raise ValueError(
                f"artifact fingerprint mismatch under {artifact_dir!r} "
                f"step {step}: manifest says {meta['fingerprint'][:16]}..., "
                f"restored content hashes to {art.fingerprint[:16]}...")
        return art

    def __repr__(self) -> str:
        side = "rkmips" if self.index is not None else "kmips-only"
        # never force the (full-corpus-hash) fingerprint just to print
        fp = (f"{self._fingerprint[:12]}" if self._fingerprint is not None
              else "<uncomputed>")
        return (f"IndexArtifact({side}, n_base={self.n_base}, "
                f"n_users={self.n_users}, pending="
                f"{'yes' if self.has_pending else 'no'}, "
                f"fingerprint={fp})")


def reconcile_compaction(snapshot: IndexArtifact, current: IndexArtifact,
                         compacted: IndexArtifact) -> IndexArtifact:
    """Re-stage the churn between ``snapshot`` and ``current`` onto
    ``compacted`` — the off-thread compaction handshake.

    The background compactor (engine/runtime.py) snapshots the live
    version V, builds ``C = V.compact()`` off-thread while traffic keeps
    staging inserts/deletes on top of V (producing V'), and must swap in
    an artifact equivalent to V' — not V. This maps V-space ids into
    C-space (V's ascending ``effective_ids`` order IS C's row order, so a
    searchsorted translates), re-applies post-snapshot deletions, and
    re-inserts post-snapshot staged rows in insertion order. O(churn)
    staging, no rebuild — cheap enough to run under the swap lock.

    ``current`` must be a delta-descendant of ``snapshot`` (same base,
    monotone deletions/slots) and ``compacted`` a delta-free compaction of
    ``snapshot``; anything else raises ``ValueError``.
    """
    if current is snapshot:
        return compacted
    if current.items is not snapshot.items and \
            current.base_fingerprint != snapshot.base_fingerprint:
        raise ValueError("reconcile_compaction: current is not a "
                         "delta-descendant of snapshot (different base "
                         "build)")
    if compacted.has_pending or compacted.n_base != snapshot.n_items:
        raise ValueError(
            f"reconcile_compaction: compacted ({compacted.n_base} base "
            f"rows, pending={compacted.has_pending}) is not a delta-free "
            f"compaction of snapshot ({snapshot.n_items} effective rows)")
    snap_del = np.asarray(snapshot.deleted)
    cur_del = np.asarray(current.deleted)
    snap_live = np.asarray(snapshot.delta_mask)
    cur_live = np.asarray(current.delta_mask)
    if current.delta_used < snapshot.delta_used \
            or (snap_del & ~cur_del).any() \
            or (~snap_live & cur_live)[:snapshot.delta_used].any():
        raise ValueError("reconcile_compaction: current is not a "
                         "delta-descendant of snapshot (deletions/staged "
                         "slots are not monotone)")
    out = compacted
    # post-snapshot deletions, as V-space ids: base rows newly retired,
    # plus snapshot-live staged slots since retired
    new_base_dead = np.where(cur_del & ~snap_del)[0]
    new_slot_dead = np.where(snap_live & ~cur_live)[0] + snapshot.n_base
    dead = np.concatenate([new_base_dead, new_slot_dead])
    if dead.size:
        ids_v = snapshot.effective_ids()  # ascending by construction
        pos = np.searchsorted(ids_v, dead)
        if (pos >= ids_v.size).any() or (ids_v[pos.clip(max=ids_v.size - 1)]
                                         != dead).any():
            raise ValueError("reconcile_compaction: post-snapshot deletion "
                             "targets a row the snapshot never served")
        out = out.delete_items(pos)
    # post-snapshot inserts: slots appended after the snapshot, still live
    fresh = np.where(cur_live[snapshot.delta_used:current.delta_used])[0] \
        + snapshot.delta_used
    if fresh.size:
        out = out.insert_items(jnp.asarray(current.delta_items)[fresh])
    return out


def load_artifact(artifact_dir: str, *, step: int | None = None
                  ) -> IndexArtifact:
    """Module-level alias of ``IndexArtifact.load``."""
    return IndexArtifact.load(artifact_dir, step=step)
