import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""SSPerf variant runner: lowers hillclimb variants of the three chosen
cells and records their roofline terms next to the baselines.

    PYTHONPATH=src python -m repro.launch.perf --variant qwen3_zero1

Variants:
  qwen3_zero1     qwen3-0.6b train_4k, pure-DP + ZeRO-1 optimizer sharding
  gat_dstpart     gat-cora ogb_products, dst-partitioned aggregation
  retrieval_sah   two-tower retrieval_cand with the SAH sketch index
"""

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402

import jax  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", required=True,
                    choices=("qwen3_zero1", "gat_dstpart", "retrieval_sah"))
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()

    from repro.configs import base as cfg_base
    from repro.launch import cells as cells_lib
    from repro.launch import roofline as rl
    from repro.launch.dryrun import _compile_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh()

    if args.variant == "qwen3_zero1":
        arch = cfg_base.get("qwen3-0.6b")
        shape = arch.shape("train_4k")
        cell = cells_lib.build_lm_cell(arch, shape, mesh, variant="zero1")
        # cost variants share the zero1 rules
        r1 = rl.from_compiled(_compile_cell(cells_lib.build_lm_cell(
            arch, shape, mesh, cost_layers=1, variant="zero1"), mesh))
        r2 = rl.from_compiled(_compile_cell(cells_lib.build_lm_cell(
            arch, shape, mesh, cost_layers=2, variant="zero1"), mesh))
        compiled = _compile_cell(cell, mesh)
        full = rl.from_compiled(compiled)
        n_l = arch.make_config().n_layers
        roof = rl.Roofline(
            flops=r1.flops + (n_l - 1) * (r2.flops - r1.flops),
            bytes_accessed=r1.bytes_accessed + (n_l - 1) * (
                r2.bytes_accessed - r1.bytes_accessed),
            coll_bytes={k: r1.coll_bytes[k] + (n_l - 1) * (
                r2.coll_bytes[k] - r1.coll_bytes[k])
                for k in r1.coll_bytes},
            peak_memory=full.peak_memory)
    elif args.variant == "gat_dstpart":
        arch = cfg_base.get("gat-cora")
        cell = cells_lib.build_gnn_cell(arch, arch.shape("ogb_products"),
                                        mesh, variant="dst_partitioned")
        compiled = _compile_cell(cell, mesh)
        roof = rl.from_compiled(compiled)
    else:
        from repro.launch.serve import build_sah_retrieval_cell
        cell = build_sah_retrieval_cell(mesh)
        compiled = _compile_cell(cell, mesh)
        roof = rl.from_compiled(compiled)

    mem = compiled.memory_analysis()
    rec = {
        "variant": args.variant,
        "roofline": roof.to_dict(),
        "memory_per_device": int(mem.temp_size_in_bytes
                                 + mem.argument_size_in_bytes
                                 + mem.output_size_in_bytes
                                 - mem.alias_size_in_bytes),
    }
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, f"{args.variant}.json"), "w") as f:
        json.dump(rec, f, indent=2)
    r = rec["roofline"]
    print(f"{args.variant}: mem/dev={rec['memory_per_device']/2**30:.2f}GiB "
          f"compute={r['compute_s']*1e3:.2f}ms "
          f"memory={r['memory_s']*1e3:.2f}ms "
          f"coll={r['collective_s']*1e3:.2f}ms dom={r['dominant']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
