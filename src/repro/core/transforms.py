"""Asymmetric MIPS->NNS transformations.

SAT (this paper, Eq. 6-7):
    I(p, c) = [p - c ; sqrt(R^2 - ||p - c||^2)]     (item side, R^{d+1})
    U(u)    = [lambda * u ; 0],  lambda = R/||u||    (user side, R^{d+1})
  Both land on the radius-R sphere, so  cos(I(p,c), U(u)) = <p - c, u> / (R ||u||)
  and MIPS over a shifted partition becomes angular NNS (Fact 1: shifting by the
  partition centroid does not change the MIPS argmax).

QNF (H2-ALSH baseline, Eq. 3-4):
    I(p) = [p ; sqrt(M^2 - ||p||^2)],  U(u) = [lambda u; 0], lambda = M/||u||
  cos(I(p), U(u)) = <p, u> / (M ||u||) -- no shifting, hence larger distortion.

Note that on the user/query side the appended coordinate is 0 and lambda > 0, so
the SRP hash sign(<a, U(u)>) = sign(<a[:d], u>): queries are hashed with the
first d rows of the projection only, identically for SAT and QNF.
"""

from __future__ import annotations

import jax.numpy as jnp


def sat_item_transform(items: jnp.ndarray, centroid: jnp.ndarray,
                       radius: jnp.ndarray) -> jnp.ndarray:
    """SAT item transform. items (n, d), centroid (d,), radius scalar -> (n, d+1).

    The appended coordinate is sqrt(max(R^2 - ||p - c||^2, 0)); the clamp guards
    numerical round-off for the farthest point (where the argument is ~0).
    """
    shifted = items - centroid[None, :]
    sq = jnp.maximum(radius ** 2 - jnp.sum(shifted * shifted, axis=-1), 0.0)
    return jnp.concatenate([shifted, jnp.sqrt(sq)[:, None]], axis=-1)


def qnf_item_transform(items: jnp.ndarray, max_norm: jnp.ndarray) -> jnp.ndarray:
    """QNF item transform of H2-ALSH. items (n, d), max_norm scalar -> (n, d+1)."""
    sq = jnp.maximum(max_norm ** 2 - jnp.sum(items * items, axis=-1), 0.0)
    return jnp.concatenate([items, jnp.sqrt(sq)[:, None]], axis=-1)


def user_transform(users: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """U(u) = [scale*u ; 0]. users (m, d) -> (m, d+1). Shared by SAT and QNF."""
    scaled = users * scale[..., None]
    zeros = jnp.zeros(users.shape[:-1] + (1,), users.dtype)
    return jnp.concatenate([scaled, zeros], axis=-1)


def centroid_and_radius(items: jnp.ndarray,
                        mask: jnp.ndarray | None = None
                        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Centroid c = mean(items) and radius R = max ||p - c|| (masked)."""
    if mask is None:
        c = jnp.mean(items, axis=0)
        r = jnp.sqrt(jnp.max(jnp.sum((items - c) ** 2, axis=-1)))
        return c, r
    w = mask.astype(items.dtype)
    denom = jnp.maximum(jnp.sum(w), 1.0)
    c = jnp.sum(items * w[:, None], axis=0) / denom
    d2 = jnp.sum((items - c) ** 2, axis=-1)
    r = jnp.sqrt(jnp.max(jnp.where(mask, d2, 0.0)))
    return c, r
