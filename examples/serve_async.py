"""Async serving: the threaded ticket pipeline with background compaction.

    PYTHONPATH=src python examples/serve_async.py

The walkthrough of DESIGN.md SS12, submit -> future -> compact-in-flight:

1. build an ``IndexArtifact`` and stand up a ``ServingRuntime`` over the
   forward retrieval server (``engine.async_server``): ``submit`` returns
   a future (``ServeTicket``) immediately, worker threads micro-batch the
   queue through the server's own flush path — answers are bitwise the
   synchronous ``flush`` on the same stream, and compile counts stay at
   one trace per batch shape;
2. stream mutations while traffic flows: ``insert_items`` /
   ``delete_items`` stage deltas and hot-swap the new version between
   flushes — pending tickets survive every swap;
3. the delta buffer fills past ``compact_fill``: the maintenance thread
   rebuilds the next base OFF-THREAD (tickets keep resolving while it
   runs), re-stages whatever churn raced the build
   (``reconcile_compaction``), swaps the merged version live, and
   persists it under the ``keep=`` GC policy;
4. deadlines: a ticket that waits past its budget fails with
   ``TicketExpired`` before dispatch instead of wedging the queue;
5. ``close()`` drains — every future resolves, then ``submit`` refuses.
"""

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import IndexArtifact, RkMIPSEngine, get_config
from repro.engine import RetrievalServer, TicketExpired
from repro.data import synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-items", type=int, default=4096)
    ap.add_argument("--m-users", type=int, default=512)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--queries", type=int, default=64)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    ki, kq, kb, kn = jax.random.split(key, 4)
    items, users = synthetic.recommendation_data(
        ki, args.n_items, args.m_users, args.dim)
    queries = synthetic.queries_from_items(kq, items, args.queries)

    cfg = get_config("sah").replace(delta_capacity=64, serve_batch_size=8)
    art = IndexArtifact.build(items, users, kb, config=cfg)
    eng = RkMIPSEngine.from_artifact(art)
    print(f"built v1: {art.n_base} items, fingerprint "
          f"{art.fingerprint[:16]}...")

    with tempfile.TemporaryDirectory() as versions:
        with eng.async_server(k=args.k, compaction=True, compact_fill=0.5,
                              poll_interval=0.01, artifact_dir=versions,
                              keep=3) as rt:
            # -- 1. tickets are futures; answers == synchronous flush -----
            tickets = rt.submit(queries)         # returns immediately
            answers = [t.result(timeout=60) for t in tickets]
            lat = sorted(t.latency for t in tickets)
            sync = RetrievalServer.from_artifact(art)
            sync.submit(queries)
            ref = sync.flush(args.k)
            assert all(np.array_equal(np.asarray(a.ids), np.asarray(r.ids))
                       for a, r in zip(answers, ref))
            print(f"{len(tickets)} tickets answered async, bitwise == "
                  f"sync flush (p50 latency {lat[len(lat) // 2] * 1e3:.1f}"
                  f" ms, compiles={rt.server.compile_count})")

            # -- 2. mutations hot-swap between flushes ---------------------
            pick = jax.random.randint(kn, (2, 40), 0, args.n_items)
            trending = 0.65 * (items[pick[0]] + items[pick[1]])
            inflight = rt.submit(queries[:16])   # tickets before the swaps
            rt.insert_items(trending)            # 40/64 slots: past the fill
            rt.delete_items([0, 7])
            for t in inflight:                   # ...survive them
                t.result(timeout=60)

            # -- 3. compaction lands in the background ---------------------
            deadline = time.monotonic() + 120
            while rt.stats.compactions < 1:
                rt.submit(queries[0]).result(timeout=60)  # traffic flows
                if time.monotonic() > deadline:
                    raise SystemExit("compaction never landed")
                time.sleep(0.02)
            merged = rt.artifact
            print(f"compacted off-thread in "
                  f"{rt.last_compaction_seconds:.2f}s: new base "
                  f"{merged.n_base} rows, churn re-staged = "
                  f"{merged.delta_used} (tickets kept resolving)")
            back = IndexArtifact.load(versions)
            assert back.fingerprint == merged.fingerprint
            print(f"merged version persisted + verified under keep=3 GC "
                  f"({back.fingerprint[:16]}...)")

            # -- 4. deadlines fail fast, pre-dispatch ----------------------
            doomed = rt.submit(queries[1], deadline=0.0)
            try:
                doomed.result(timeout=30)
            except TicketExpired as e:
                print(f"deadline honored: {e}")

            st = rt.stats
            print(f"stats: {st.completed} completed / {st.expired} expired "
                  f"over {st.batches} batches, {st.swaps} swaps, "
                  f"{st.compactions} compaction")
        # -- 5. the context manager drained and closed the runtime --------
        try:
            rt.submit(queries[0])
        except RuntimeError as e:
            print(f"closed: {e}")


if __name__ == "__main__":
    main()
