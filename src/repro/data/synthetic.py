"""Synthetic datasets.

The paper evaluates on five MF/NMF-factorized recommendation datasets
(Amazon-Auto, Amazon-CDs, MovieLens, Music100, Netflix; d=100). Offline we
generate matched surrogates: non-negative low-rank factor products, which
reproduce the two properties the algorithms exploit -- concentrated positive
inner products (angles << pi/2) and a long-tailed item-norm distribution.
`PAPER_DATASETS` records the real (n, m) sizes; benchmarks run scaled-down
versions sized for single-CPU wall clock, with the scale factor reported.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PaperDataset:
    name: str
    n_items: int
    m_users: int
    d: int = 100


PAPER_DATASETS = {
    "amazon-auto": PaperDataset("amazon-auto", 925387, 3873247),
    "amazon-cds": PaperDataset("amazon-cds", 64443, 75258),
    "movielens": PaperDataset("movielens", 10681, 71567),
    "music100": PaperDataset("music100", 1000000, 1000000),
    "netflix": PaperDataset("netflix", 17770, 480189),
}


def mf_factors(key: jax.Array, n: int, d: int, rank: int = 16,
               kind: str = "nmf", h: jnp.ndarray | None = None,
               noise: float = 1.0, skew: float = 0.1) -> jnp.ndarray:
    """Rows of a factor matrix: low-rank structure matching MF outputs.

    Parameters calibrated (rank 16, noise 1.0, skew 0.1) so the RkMIPS
    workload is non-degenerate: result sets are non-empty, the Simpfer/cone
    bounds prune most-but-not-all users, and the item scan actually runs --
    mirroring the pruning profiles the paper reports.
    """
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind == "nmf":
        w = jnp.abs(jax.random.normal(k1, (n, rank)))
        if h is None:
            h = jnp.abs(jax.random.normal(k2, (rank, d)))
        x = w @ h / rank + noise * jnp.abs(jax.random.normal(k3, (n, d)))
        scale = jnp.exp(skew * jax.random.normal(k4, (n, 1)))
        return (x * scale).astype(jnp.float32)
    if kind == "gaussian":
        return jax.random.normal(k1, (n, d), dtype=jnp.float32)
    raise ValueError(kind)


def recommendation_data(key: jax.Array, n_items: int, m_users: int, d: int,
                        rank: int = 16, kind: str = "nmf"):
    """(items (n,d), users (m,d)) sharing the item-factor structure."""
    ki, ku, kh = jax.random.split(key, 3)
    h = jnp.abs(jax.random.normal(kh, (rank, d))) if kind == "nmf" else None
    items = mf_factors(ki, n_items, d, rank, kind, h=h)
    users = mf_factors(ku, m_users, d, rank, kind, h=h)
    return items, users


def queries_from_items(key: jax.Array, items: jnp.ndarray, nq: int,
                       top_frac: float = 0.2) -> jnp.ndarray:
    """Paper setup: queries drawn from the item matrix. We sample from the
    top norm fraction so result sets are non-trivially sized."""
    norms = jnp.linalg.norm(items, axis=-1)
    order = jnp.argsort(-norms)
    hi = max(nq, int(items.shape[0] * top_frac))
    pick = jax.random.choice(key, hi, (nq,), replace=False)
    return items[order[pick]]


def lm_token_batches(key: jax.Array, batch: int, seq: int, vocab: int,
                     n_batches: int = 0):
    """Zipf-ish synthetic token stream; yields {"tokens", "labels"}."""
    i = 0
    while True:
        key, sub = jax.random.split(key)
        # zipf via transformed uniform: rank ~ u^(-1/s), s ~ 1.1
        u = jax.random.uniform(sub, (batch, seq + 1), minval=1e-6)
        ranks = jnp.clip((u ** -0.9) - 1.0, 0, vocab - 1).astype(jnp.int32)
        yield {"tokens": ranks[:, :-1], "labels": ranks[:, 1:]}
        i += 1
        if n_batches and i >= n_batches:
            return
