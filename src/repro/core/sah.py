"""SAH: Shifting-aware Asymmetric Hashing for RkMIPS (Algorithms 4-5).

Combines SA-ALSH (core/sa_alsh.py) over items with cone blocking
(core/cone.py) and Simpfer lower bounds (core/simpfer.py) over users.

Indexing (Algorithm 4):
  1. sort items by descending norm; P' = the n_top highest-norm items;
  2. exact lower-bound arrays L_u over P' for every user (batched matmul);
  3. SA-ALSH index over P \\ P';
  4. cone blocks over unit users; block lower bounds L_B = min over leaf.

Query (Algorithm 5), per query q, fully batched over users:
  1. node-level bound (Lemma 2) kills whole blocks: ub_B < L_B[k-1];
  2. vector-level bound (Lemma 3) kills users: ub_u < L_u[k-1];
  3. tau = <u, q> computed densely (one (m,d) matvec -- on TPU this is
     cheaper than gathering survivors; the bounds' value is keeping users out
     of the expensive scan, and we report both pruning stages in the stats);
     "no" if tau < L_u[k-1]; "yes" if tau >= ||p_k|| (k-th largest item norm);
  4. survivors are compacted (cone order => chunk locality: users in the same
     cone have correlated early-exit depths, so chunks finish together) and
     run through the counting scan decide_count() in fixed-size chunks.

The same engine gives every paper baseline via two switches:
  user blocking: "cone" (SAH / H2-Cone) or "norm" (Simpfer-style blocks --
     with unit users, Simpfer's norm blocking degenerates to arbitrary
     contiguous blocks; see DESIGN.md)
  item scan: transform "sat" + scan "sketch" (SA-ALSH), transform "qnf"
     (H2-ALSH), scan "exact" (Simpfer's linear scan).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import cone as _cone
from repro.core import sa_alsh as _alsh
from repro.core import simpfer as _simpfer


class SAHIndex(NamedTuple):
    """Everything the query phase needs. Users live in cone-leaf order."""

    alsh: _alsh.SAALSHIndex          # over P \ P'
    users: jnp.ndarray               # (m_pad, d) unit users, leaf order
    user_ids: jnp.ndarray            # (m_pad,) original user row
    user_mask: jnp.ndarray           # (m_pad,) real (non-duplicate) users
    center: jnp.ndarray              # (n_blocks, d)
    omega: jnp.ndarray               # (n_blocks,)
    theta: jnp.ndarray               # (m_pad,)
    user_lb: jnp.ndarray             # (m_pad, kmax)
    block_lb: jnp.ndarray            # (n_blocks, kmax)
    top_norms: jnp.ndarray           # (n_top,) norms of P', descending
    top_items: jnp.ndarray           # (n_top, d) P' item vectors
    top_ids: jnp.ndarray             # (n_top,) original rows of P'

    @property
    def n_blocks(self) -> int:
        return self.center.shape[0]

    @property
    def kmax(self) -> int:
        return self.user_lb.shape[1]

    @property
    def n_users(self) -> int:
        return self.users.shape[0]


def build(items: jnp.ndarray, users: jnp.ndarray, key: jax.Array, *,
          k_max: int = 50, n_top: int | None = None, leaf_size: int = 32,
          b: float = 0.5, n_bits: int = 128, tile: int = 512,
          max_partitions: int = 64, transform: str = "sat",
          blocking: str = "cone") -> SAHIndex:
    """Build the SAH index (Algorithm 4). items (n,d), users (m,d)."""
    if n_top is None:
        n_top = 2 * k_max
    k_idx, k_cone = jax.random.split(jax.random.fold_in(key, 0))

    norms = jnp.linalg.norm(items, axis=-1)
    order = jnp.argsort(-norms)
    items_sorted = items[order]
    top_items = items_sorted[:n_top]
    top_ids = order[:n_top].astype(jnp.int32)
    top_norms = norms[order][:n_top]
    rest = items_sorted[n_top:]

    alsh = _alsh.build_index(rest, k_idx, b=b, n_bits=n_bits, tile=tile,
                             max_partitions=max_partitions,
                             transform=transform)
    # alsh.item_ids index `rest`; shift them back to original rows.
    alsh = alsh._replace(item_ids=jnp.where(
        alsh.item_ids >= 0,
        jnp.take(order.astype(jnp.int32),
                 jnp.clip(alsh.item_ids, 0, None) + n_top),
        -1))

    unorm = jnp.linalg.norm(users, axis=-1, keepdims=True)
    users_unit = users / jnp.maximum(unorm, 1e-12)

    if blocking == "cone":
        blocks, padded, mask = _cone.build_cone_blocks(users_unit, k_cone,
                                                       leaf_size)
        perm = blocks.perm
        center, omega, theta = blocks.center, blocks.omega, blocks.theta
    elif blocking == "norm":
        # Simpfer-style blocking: contiguous chunks (unit users degenerate
        # Simpfer's norm intervals to a single interval; see DESIGN.md).
        padded, mask, n_leaves = _cone.pad_users(users_unit, leaf_size)
        perm = jnp.arange(padded.shape[0], dtype=jnp.int32)
        xl = padded.reshape(n_leaves, leaf_size, -1)
        center = jnp.mean(xl, axis=1)
        cnorm = jnp.linalg.norm(center, axis=-1, keepdims=True)
        cos = jnp.einsum("bld,bd->bl", xl, center) / jnp.maximum(cnorm, 1e-12)
        theta_2d = jnp.arccos(jnp.clip(cos, -1.0, 1.0))
        omega = jnp.max(theta_2d, axis=-1)
        theta = theta_2d.reshape(-1)
    else:
        raise ValueError(f"unknown blocking {blocking!r}")

    users_leaf = padded[perm]
    m = users.shape[0]
    user_ids = (perm % m).astype(jnp.int32)
    user_mask = mask[perm]

    lb = _simpfer.user_lower_bounds(users_leaf, top_items, k_max)
    n_blocks = center.shape[0]
    block_lb = _simpfer.block_lower_bounds(
        jnp.where(user_mask[:, None], lb, jnp.inf), n_blocks)
    # All-padding blocks (impossible with cyclic padding, but be safe):
    block_lb = jnp.where(jnp.isfinite(block_lb), block_lb, -jnp.inf)

    return SAHIndex(alsh=alsh, users=users_leaf, user_ids=user_ids,
                    user_mask=user_mask, center=center, omega=omega,
                    theta=theta, user_lb=lb, block_lb=block_lb,
                    top_norms=top_norms, top_items=top_items, top_ids=top_ids)


class QueryStats(NamedTuple):
    blocks_alive: jnp.ndarray    # after Lemma 2
    users_alive: jnp.ndarray     # after Lemma 3
    n_no_lb: jnp.ndarray         # decided no by tau < L[k-1]
    n_yes_norm: jnp.ndarray      # decided yes by tau >= ||p_k||
    n_scan: jnp.ndarray          # users that needed the item scan
    tiles_scanned: jnp.ndarray   # total tile-visits across chunks
    chunks: jnp.ndarray


def rkmips_impl(index: SAHIndex, q: jnp.ndarray, k: int, *, n_cand: int = 64,
                scan: str = "sketch", chunk: int = 256,
                tie_eps: float = 0.0):
    """Algorithm 5 for one query, undecorated. Returns (pred (m_pad,),
    QueryStats).

    pred is in cone-leaf order; use predictions_to_original() to map back.
    tie_eps: relative tie tolerance, must match the oracle (core/exact.py).
    Call ``rkmips`` (the jitted alias) directly; this impl exists for
    composition inside outer transforms — a nested ``jax.jit`` under
    ``shard_map`` miscompiles on this toolchain (caught by the engine's
    sharded-equivalence test), so ``repro.engine.sharding`` traces the raw
    body instead.
    """
    m_pad = index.n_users
    chunk = min(chunk, m_pad)
    leaf = m_pad // index.n_blocks
    qn = jnp.linalg.norm(q)
    eps = tie_eps * qn
    # f32 slack: the cone bounds go through arccos/cos roundtrips whose
    # relative error is ~1e-4; without slack a mathematically-tight bound
    # can flip a pruning decision (caught by the property tests).
    slack = 2e-4 * qn + eps

    # --- Lemma 2: block-level pruning -------------------------------------
    node_ub, phi = _cone.node_upper_bound(q, _cone.ConeBlocks(
        perm=jnp.arange(m_pad, dtype=jnp.int32), center=index.center,
        omega=index.omega, theta=index.theta))
    block_alive = node_ub >= index.block_lb[:, k - 1] - slack
    # --- Lemma 3: vector-level pruning ------------------------------------
    phi_u = jnp.repeat(phi, leaf)
    vec_ub = qn * jnp.cos(jnp.abs(phi_u - index.theta))
    user_alive = (index.user_mask & jnp.repeat(block_alive, leaf)
                  & (vec_ub >= index.user_lb[:, k - 1] - slack))

    # --- exact tau + O(1) decisions ---------------------------------------
    tau = index.users @ q
    no_lb = index.user_lb[:, k - 1] > tau + eps
    yes_norm = tau >= index.top_norms[k - 1]
    undecided = user_alive & ~no_lb & ~yes_norm
    count0 = _simpfer.init_count(index.user_lb, tau + eps)

    # --- compact survivors (cone order preserved) and scan in chunks ------
    und_ids = jnp.argsort(~undecided)                     # undecided first
    n_und = jnp.sum(undecided)
    pred0 = yes_norm & index.user_mask

    def cond(state):
        ci, _, _ = state
        return (ci * chunk) < n_und

    def body(state):
        ci, pred, tiles = state
        ids = jax.lax.dynamic_slice(und_ids, (ci * chunk,), (chunk,))
        active = (ci * chunk + jnp.arange(chunk)) < n_und
        users_c = jnp.take(index.users, ids, axis=0)
        taus_c = jnp.take(tau, ids)
        counts_c = jnp.take(count0, ids)
        is_yes, t_vis = _alsh.decide_count(index.alsh, users_c, taus_c,
                                           counts_c, active, k,
                                           n_cand=n_cand, scan=scan, eps=eps)
        pred = pred.at[ids].set(jnp.where(active, is_yes, pred[ids]))
        return ci + 1, pred, tiles + t_vis

    n_chunks, pred, tiles = jax.lax.while_loop(
        cond, body, (jnp.asarray(0, jnp.int32), pred0,
                     jnp.asarray(0, jnp.int32)))

    stats = QueryStats(
        blocks_alive=jnp.sum(block_alive),
        users_alive=jnp.sum(user_alive),
        n_no_lb=jnp.sum(no_lb & index.user_mask),
        n_yes_norm=jnp.sum(yes_norm & index.user_mask),
        n_scan=n_und,
        tiles_scanned=tiles,
        chunks=n_chunks,
    )
    return pred, stats


rkmips = functools.partial(
    jax.jit, static_argnames=("k", "n_cand", "scan", "chunk", "tie_eps"),
)(rkmips_impl)


def rkmips_batch(index: SAHIndex, queries: jnp.ndarray, k: int, *,
                 n_cand: int = 64, scan: str = "sketch", chunk: int = 256,
                 tie_eps: float = 0.0):
    """Batch driver: (nq, d) queries -> (pred (nq, m_pad), stats stacked)."""
    fn = functools.partial(rkmips, index, k=k, n_cand=n_cand, scan=scan,
                           chunk=chunk, tie_eps=tie_eps)
    return jax.lax.map(lambda q: fn(q), queries)


def predictions_to_original(index: SAHIndex, pred: jnp.ndarray,
                            n_users: int) -> jnp.ndarray:
    """Map leaf-order predictions (..., m_pad) back to original rows (..., m).

    Every padding convention in the stack (SS2 cyclic user padding; the
    sharding-time dead duplicate leaves of ``engine/sharding.py::pad_index``)
    must keep this mapping exact: padded rows are masked (``user_mask`` is
    False) so they can never set an original row, and the scatter drops any
    id outside [0, n_users) outright — a phantom id (e.g. a -1 sentinel)
    cannot silently clamp onto a real user.
    """
    masked = (pred & index.user_mask).astype(jnp.int32)
    out = jnp.zeros(pred.shape[:-1] + (n_users,), jnp.int32)
    out = out.at[..., index.user_ids].max(masked, mode="drop")
    return out > 0
