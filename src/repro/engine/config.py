"""EngineConfig + the baseline method registry (DESIGN.md SS7).

Every knob that configures an (R)kMIPS run lives in one frozen, hashable
``EngineConfig``: the index-build parameters of ``core/sah.py::build`` and
the query parameters of ``core/sah.py::rkmips``. The paper's whole baseline
matrix (DESIGN.md SS3) is then a *registry* of preset configs — the engine
never re-encodes the method grid by hand:

  | name        | user blocking | item transform | item scan |
  |-------------|---------------|----------------|-----------|
  | sah         | cone          | sat            | sketch    |
  | sa-simpfer  | norm          | sat            | sketch    |
  | h2-cone     | cone          | qnf            | sketch    |
  | h2-simpfer  | norm          | qnf            | sketch    |
  | simpfer     | norm          | sat (unused)   | exact     |
  | exact       | cone          | sat (unused)   | exact     |

"exact" keeps SAH's cone pruning but scans items linearly — an exact
configuration (the bounds are conservative and the linear scan is Simpfer's
oracle-faithful counting rule), useful as an in-engine ground truth.

``tie_eps`` is part of the config on purpose: build, query and the exact
oracle must all use the same tie tolerance (see core/exact.py), and carrying
it in loose kwargs made every caller re-remember ``1e-5`` twice. The default
matches the repo-wide convention for queries drawn from the item set.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

TIE_EPS_DEFAULT = 1e-5

_TRANSFORMS = ("sat", "qnf")
_BLOCKINGS = ("cone", "norm")
_SCANS = ("sketch", "exact")
_BUILD_SHARDINGS = ("auto", "single", "sharded")
_SCAN_PRECISIONS = ("f32", "int8")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """All knobs of one (R)kMIPS engine run. Frozen and hashable.

    Index-build knobs (core/sah.py::build):
      k_max:          largest query-time k the index supports.
      n_top:          |P'| top-norm items held out for Simpfer lower bounds
                      (None -> 2 * k_max, the build default).
      leaf_size:      cone-block leaf size N0.
      b:              norm-partition interval ratio (Algorithm 1).
      n_bits:         SRP sketch width (bits; W = n_bits // 32 words).
      tile:           item-scan tile size (rows per Cauchy-Schwarz bound).
      max_partitions: cap on norm partitions T.
      transform:      "sat" (SA-ALSH) or "qnf" (H2-ALSH).
      blocking:       "cone" (Cone-Tree leaves) or "norm" (Simpfer blocks).

    Query knobs (core/sah.py::rkmips):
      scan:    "sketch" (Hamming candidates) or "exact" (linear scan).
      n_cand:  sketch candidates re-ranked per tile.
      chunk:   survivor-compaction chunk size.
      tie_eps: relative tie tolerance, shared with the oracle (core/exact.py).
      scan_precision: "f32" (stock float tile scan) or "int8" (quantized
               screen + banded exact re-rank fed by the fused Pallas
               kernel, DESIGN.md SS13). Execution-only: predictions are
               bitwise identical either way, so — like ``build_sharding``
               — it is excluded from the artifact fingerprint and from
               ``attach`` config equality, and the plan phase ignores it.
      scan_budget: per-query tile-visit cap for the reverse execute phase
               (0 = uncapped, the default). The serving gateway's defence
               against adversarial queries crafted to defeat SRP-code
               pruning (DESIGN.md SS15): once a query's charged
               tile-visits reach the budget, its remaining lanes resolve
               conservatively ("not in the audience") and the result is
               flagged ``truncated`` — never silently wrong. Execution-
               only like ``scan_precision`` (excluded from fingerprints
               and ``attach`` equality), and deliberately NOT part of
               ``query_kwargs()``: the engine threads it as a *traced*
               int32 operand so tenants with different budgets share one
               compiled trace.

    Online-serving knobs (engine/serving.py, DESIGN.md SS8, SS14):
      serve_batch_size:     micro-batch size the RetrievalServer pads
                            accumulated queries to (static shape: exactly
                            one compile per distinct batch size).
      serve_buckets:        ascending dispatch sizes below
                            ``serve_batch_size`` the serving runtime may
                            pad a partial micro-batch up to instead of the
                            full batch (e.g. ``(1, 2, 4)`` for a
                            power-of-two ladder under a batch of 8). Empty
                            (the default) keeps the single-size contract:
                            every dispatch pads to ``serve_batch_size``.
                            Each rung is one more static shape — one trace
                            each, all precompiled by ``warmup()`` — and
                            bucket-padded dispatch is bitwise equal to the
                            unbucketed flush (padding is dead either way).
                            Execution-only like ``serve_batch_size``: not
                            part of any build recipe or cache key.
      serve_cache_capacity: LRU capacity of the built-serving-state cache
                            (states are keyed by the artifact fingerprint
                            + the config's item-index recipe).

    Artifact-lifecycle knobs (engine/artifact.py, DESIGN.md SS10):
      delta_capacity: slots of the staged-insert delta buffer an
                      ``IndexArtifact`` carries between compactions. The
                      capacity is a static shape: attached engines compile
                      the delta pipeline at most once per batch shape, no
                      matter how often the corpus churns. Not part of any
                      build recipe (two configs differing only here share
                      serving state and produce identical indexes).

    Build-execution knobs (engine/build.py, DESIGN.md SS11):
      build_sharding: how the staged build pipeline runs its row-parallel
                      stages — "auto" (shard when the policy carries a
                      multi-device mesh, the default), "single" (always
                      single-device), or "sharded" (require a mesh).
                      Execution-only: the built index is bitwise identical
                      either way, so the knob is excluded from the
                      artifact fingerprint and from ``attach`` config
                      equality (like ``delta_capacity``, it is not part of
                      the build recipe).
    """

    k_max: int = 50
    n_top: int | None = None
    leaf_size: int = 32
    b: float = 0.5
    n_bits: int = 128
    tile: int = 512
    max_partitions: int = 64
    transform: str = "sat"
    blocking: str = "cone"
    scan: str = "sketch"
    n_cand: int = 64
    chunk: int = 256
    tie_eps: float = TIE_EPS_DEFAULT
    serve_batch_size: int = 8
    serve_buckets: tuple = ()
    serve_cache_capacity: int = 4
    delta_capacity: int = 256
    build_sharding: str = "auto"
    scan_precision: str = "f32"
    scan_budget: int = 0

    def __post_init__(self):
        if self.build_sharding not in _BUILD_SHARDINGS:
            raise ValueError(f"build_sharding must be one of "
                             f"{_BUILD_SHARDINGS}, "
                             f"got {self.build_sharding!r}")
        if self.transform not in _TRANSFORMS:
            raise ValueError(f"transform must be one of {_TRANSFORMS}, "
                             f"got {self.transform!r}")
        if self.blocking not in _BLOCKINGS:
            raise ValueError(f"blocking must be one of {_BLOCKINGS}, "
                             f"got {self.blocking!r}")
        if self.scan not in _SCANS:
            raise ValueError(f"scan must be one of {_SCANS}, "
                             f"got {self.scan!r}")
        if self.scan_precision not in _SCAN_PRECISIONS:
            raise ValueError(f"scan_precision must be one of "
                             f"{_SCAN_PRECISIONS}, "
                             f"got {self.scan_precision!r}")
        for name in ("k_max", "leaf_size", "n_bits", "tile",
                     "max_partitions", "n_cand", "chunk",
                     "serve_batch_size", "serve_cache_capacity",
                     "delta_capacity"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1, "
                                 f"got {getattr(self, name)}")
        if self.scan_budget < 0:
            raise ValueError(f"scan_budget must be >= 0 (0 = uncapped), "
                             f"got {self.scan_budget}")
        if self.n_top is not None and self.n_top < self.k_max:
            raise ValueError(f"n_top ({self.n_top}) must be >= k_max "
                             f"({self.k_max})")
        if not 0.0 < self.b < 1.0:
            raise ValueError(f"b must be in (0, 1), got {self.b}")
        if self.tie_eps < 0.0:
            raise ValueError(f"tie_eps must be >= 0, got {self.tie_eps}")
        if self.n_bits % 32 != 0:
            raise ValueError(f"n_bits must be a multiple of 32, "
                             f"got {self.n_bits}")
        # normalize to a tuple so the config stays hashable when callers
        # pass a list; validation then pins the ladder shape
        object.__setattr__(self, "serve_buckets",
                           tuple(self.serve_buckets))
        for bkt in self.serve_buckets:
            if not isinstance(bkt, int) or isinstance(bkt, bool):
                raise ValueError(f"serve_buckets must hold ints, got "
                                 f"{bkt!r}")
            if not 1 <= bkt <= self.serve_batch_size:
                raise ValueError(f"serve_buckets entries must be in "
                                 f"[1, serve_batch_size="
                                 f"{self.serve_batch_size}], got {bkt}")
        if list(self.serve_buckets) != sorted(set(self.serve_buckets)):
            raise ValueError(f"serve_buckets must be strictly increasing, "
                             f"got {self.serve_buckets}")

    def replace(self, **overrides) -> "EngineConfig":
        """A copy with the given fields replaced (validation re-runs)."""
        return dataclasses.replace(self, **overrides)

    def bucket_ladder(self) -> tuple:
        """The ascending dispatch sizes serving may pad a micro-batch to:
        ``serve_buckets`` plus the full ``serve_batch_size`` as the top
        rung. With no buckets configured this is the single-size ladder
        ``(serve_batch_size,)`` — the pre-bucketing contract."""
        return tuple(b for b in self.serve_buckets
                     if b < self.serve_batch_size) + (self.serve_batch_size,)

    def build_kwargs(self) -> dict:
        """Kwargs for core/sah.py::build (index construction)."""
        return dict(k_max=self.k_max, n_top=self.n_top,
                    leaf_size=self.leaf_size, b=self.b, n_bits=self.n_bits,
                    tile=self.tile, max_partitions=self.max_partitions,
                    transform=self.transform, blocking=self.blocking)

    def query_kwargs(self) -> dict:
        """Kwargs for core/sah.py::rkmips / rkmips_batch."""
        return dict(scan=self.scan, n_cand=self.n_cand, chunk=self.chunk,
                    tie_eps=self.tie_eps,
                    scan_precision=self.scan_precision)

    def kmips_build_kwargs(self, n_items: int) -> dict:
        """Kwargs for core/sa_alsh.py::build_index over ``n_items`` rows.

        The single source of truth for the kMIPS/serving index recipe: the
        engine's kMIPS index, ``build_serving_state``, ``serving_codes``
        and the ``ServingCache`` key all derive from it, so a new build
        knob threads through every builder *and* the cache key at once —
        a stale key can't serve wrong codes as a "hit". The tile is
        clamped to the corpus so every path builds identical shapes.
        """
        return dict(b=self.b, n_bits=self.n_bits,
                    tile=min(self.tile, n_items),
                    max_partitions=self.max_partitions,
                    transform=self.transform)


# ---------------------------------------------------------------------------
# Method registry.
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, EngineConfig] = {}
_DISPLAY: dict[str, str] = {}


def register(name: str, config: EngineConfig, *,
             display: str | None = None) -> None:
    """Register a named preset. Names are case-insensitive; re-registering
    an existing name replaces it (configs are values, not identities)."""
    key = name.lower()
    _REGISTRY[key] = config
    _DISPLAY[key] = display if display is not None else name


def get_config(name: str) -> EngineConfig:
    """The preset registered under ``name`` (case-insensitive)."""
    try:
        return _REGISTRY[name.lower()]
    except KeyError:
        raise KeyError(f"unknown engine method {name!r}; known: "
                       f"{', '.join(sorted(_REGISTRY))}") from None


def method_names() -> tuple[str, ...]:
    """All registered method names, registration order."""
    return tuple(_REGISTRY)


def display_name(name: str) -> str:
    """The paper-style display name ("sah" -> "SAH")."""
    get_config(name)   # raise on unknown
    return _DISPLAY[name.lower()]


register("sah", EngineConfig(), display="SAH")
register("sa-simpfer", EngineConfig(blocking="norm"), display="SA-Simpfer")
register("h2-cone", EngineConfig(transform="qnf"), display="H2-Cone")
register("h2-simpfer", EngineConfig(transform="qnf", blocking="norm"),
         display="H2-Simpfer")
register("simpfer", EngineConfig(blocking="norm", scan="exact"),
         display="Simpfer")
register("exact", EngineConfig(scan="exact"), display="Exact")

# The paper's Fig.1/Fig.2 comparison grid (DESIGN.md SS3). "exact" is the
# in-engine oracle configuration, not a benchmarked baseline.
PAPER_BASELINES: tuple[str, ...] = ("sah", "sa-simpfer", "h2-cone",
                                    "h2-simpfer", "simpfer")
