"""The paper's motivating use case, end to end: a service promotes an item
and asks "which users would actually see it?" -- RkMIPS over two-tower
embeddings.

    PYTHONPATH=src python examples/reverse_recommend.py

Pipeline: train two-tower (briefly) -> embed users and items -> build the
full SAH index (item partitions + cone-blocked users + lower bounds) ->
answer reverse queries for promoted items and compare against exact.
Contrast with forward kMIPS on the same queries (Table 2 of the paper:
the two problems' answers barely overlap).
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import RkMIPSEngine
from repro.configs import base as cfg_base
from repro.core import metrics
from repro.models import recsys as rec_lib
from repro.train import optimizer as opt_lib
from repro.train.trainer import TrainState, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--n-items", type=int, default=4096)
    ap.add_argument("--m-users", type=int, default=8192)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    cfg = cfg_base.get("two-tower-retrieval").make_smoke_config()
    key = jax.random.PRNGKey(0)
    params = rec_lib.init_twotower_params(key, cfg)
    opt = opt_lib.adamw(1e-3)
    step = jax.jit(make_train_step(
        lambda p, b: rec_lib.twotower_loss(p, b, cfg), opt))
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    for i in range(args.steps):
        kk = jax.random.fold_in(key, i)
        b = 256
        batch = {
            "user_feats": jnp.stack(
                [jax.random.randint(jax.random.fold_in(kk, j), (b,), 0, v)
                 for j, v in enumerate(cfg.user_embedding.vocab_sizes)], -1),
            "item_feats": jnp.stack(
                [jax.random.randint(jax.random.fold_in(kk, 7 + j), (b,), 0,
                                    v)
                 for j, v in enumerate(cfg.item_embedding.vocab_sizes)], -1),
            "log_q": jnp.zeros((b,))}
        state, m = step(state, batch)
    print(f"two-tower trained ({args.steps} steps, loss "
          f"{float(m['loss']):.3f})")

    ki, ku = jax.random.fold_in(key, 100), jax.random.fold_in(key, 200)
    item_feats = jnp.stack(
        [jax.random.randint(jax.random.fold_in(ki, j), (args.n_items,), 0, v)
         for j, v in enumerate(cfg.item_embedding.vocab_sizes)], -1)
    user_feats = jnp.stack(
        [jax.random.randint(jax.random.fold_in(ku, j), (args.m_users,), 0, v)
         for j, v in enumerate(cfg.user_embedding.vocab_sizes)], -1)
    items = rec_lib.item_tower(state.params, item_feats, cfg)
    users = rec_lib.user_tower(state.params, user_feats, cfg)

    eng = RkMIPSEngine("sah").build(items, users, jax.random.fold_in(key, 7))
    print(f"SAH index over embeddings built in {eng.build_seconds:.2f}s")

    # promote the 4 highest-norm items
    norms = jnp.linalg.norm(items, axis=-1)
    promoted = jnp.argsort(-norms)[:4]
    queries = items[promoted]

    res = eng.query_batch(queries, args.k)
    po = res.predictions
    truth = eng.oracle(queries, args.k)
    f1 = metrics.f1_score(po, truth)

    # forward kMIPS top-k users by raw inner product (the wrong tool)
    uu = users / jnp.linalg.norm(users, axis=-1, keepdims=True)
    fwd_scores = queries @ uu.T
    _, fwd_top = jax.lax.top_k(fwd_scores, args.k)
    for i, item_id in enumerate(np.asarray(promoted)):
        audience = np.where(np.asarray(po[i]))[0]
        fwd = set(np.asarray(fwd_top[i]).tolist())
        overlap = len(fwd & set(audience.tolist()))
        print(f"item {item_id}: RkMIPS audience={len(audience)} users "
              f"(F1 vs exact {float(f1[i]):.3f}); forward-kMIPS top-{args.k} "
              f"overlaps only {overlap}/{args.k} -- the reverse problem is "
              f"genuinely different")


if __name__ == "__main__":
    main()
