"""Mesh-sharded execution paths for the RkMIPS engine (DESIGN.md SS7-SS8).

The engine's two heavy loops shard cleanly because both are embarrassingly
parallel along one axis:

  * RkMIPS (Algorithm 5) is independent **per user**: the dense tau matvec,
    the Lemma 2/3 bounds and the counting scan of a user lane never look at
    another lane. So the user side of the ``SAHIndex`` (leaf-ordered users,
    angles, lower bounds, cone blocks) is row-sharded over every mesh axis,
    the item side (SA-ALSH index, top-norm prefix) is replicated, and each
    shard runs the stock batched plan/execute pipeline
    (``core/sah.py::rkmips_batch_impl``, DESIGN.md SS9) on its slice of the
    user rows for the WHOLE query batch at once; one tiled all-gather
    reassembles the (nq, m_pad) prediction grid and a psum merges the
    counters. The body is a single flat while_loop over the shard-local
    cross-query work queue -- no nested jit, no scan-of-while, no Python
    loop over queries -- so it traces exactly once per batch shape at any
    batch size (pinned by the compile-count test) and is safe under
    ``shard_map`` where the old per-query drivers (nested jit / lax.map)
    miscompiled on jax 0.4.x. Predictions are bitwise identical to the
    unsharded run (asserted in tests/test_engine.py): queue compaction
    regroups lanes but each lane's decision is self-contained.

  * kMIPS shards along **items**, reusing the proven pattern of
    ``launch/serve.py::sah_retrieve_step``: each shard Hamming-scans its code
    slice, re-ranks its local top-``n_cand`` exactly, keeps a local top-k,
    and one tiny all-gather + final top-k merges the winners — wire bytes
    per query are O(shards * k), independent of the item count. The sharded
    scan is single-pass (no tile early-exit; latency on a mesh is bounded by
    the slowest shard, so the bound check buys nothing).

Any user/item count shards over any mesh: when a count does not divide the
device count, the arrays are padded up to the next multiple with **dead**
rows before layout — cone blocks by cyclically duplicated leaves whose
``user_mask`` is False and whose block lower bound is +inf (so Lemma 2 kills
them before any work happens; the same convention as the SS2 cyclic user
padding), item rows by masked rows whose scores are forced to ``-inf``.
Results are bitwise equal to the unsharded path after mask stripping
(``predictions_to_original`` / the ``item_mask``), and the per-user /
per-block counters in ``QueryStats`` are unchanged because dead padding
never prunes, scans, or counts.

Sharding enters only via ``ShardingPolicy`` (DESIGN.md SS5): ``mesh=None``
routes every entry point to the identical single-device computation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import sa_alsh as _alsh
from repro.core import sah as _sah
from repro.dist.policy import ShardingPolicy
from repro.kernels import ops as kops

_BIG_HAMMING = jnp.int32(1 << 30)
_NEG = -jnp.inf

# SAHIndex fields whose leading axis is the (padded, leaf-ordered) user axis
# or the cone-block axis; everything else (the SA-ALSH item index, the
# top-norm prefix) is replicated.
_USER_AXIS_FIELDS = ("users", "user_ids", "user_mask", "theta", "user_lb")
_BLOCK_AXIS_FIELDS = ("center", "omega", "block_lb")


def n_shards(policy: ShardingPolicy) -> int:
    """Total device count of the policy's mesh (1 without a mesh)."""
    return policy.device_count


def pad_index(index: _sah.SAHIndex, shards: int) -> _sah.SAHIndex:
    """Pad the cone-block axis to a multiple of ``shards`` with dead leaves.

    Padding leaves are cyclic duplicates of real leaves (valid unit vectors,
    so every bound and matvec stays finite — the SS2 convention), except:
    ``user_mask`` is False on every padded row and ``block_lb`` is +inf on
    every padded block, so Lemma 2 prunes the block before any per-user work
    and no counter, prediction, or scan ever sees the duplicates. The result
    is query-for-query bitwise equal to the unpadded index after mask
    stripping. No-op when ``n_blocks`` already divides.
    """
    nb = index.n_blocks
    nb_pad = -(-nb // shards) * shards
    if nb_pad == nb:
        return index
    leaf = index.n_users // nb
    pad_blocks = (jnp.arange(nb, nb_pad, dtype=jnp.int32)) % nb
    pad_rows = (pad_blocks[:, None] * leaf
                + jnp.arange(leaf, dtype=jnp.int32)[None, :]).reshape(-1)

    def dup(x, rows):
        return jnp.concatenate([x, jnp.take(x, rows, axis=0)], axis=0)

    return index._replace(
        users=dup(index.users, pad_rows),
        user_ids=dup(index.user_ids, pad_rows),
        user_mask=jnp.concatenate(
            [index.user_mask, jnp.zeros((pad_rows.shape[0],), bool)]),
        theta=dup(index.theta, pad_rows),
        user_lb=dup(index.user_lb, pad_rows),
        center=dup(index.center, pad_blocks),
        omega=dup(index.omega, pad_blocks),
        block_lb=jnp.concatenate(
            [index.block_lb,
             jnp.full((nb_pad - nb, index.kmax), jnp.inf,
                      index.block_lb.dtype)]),
    )


def pad_item_rows(items: jnp.ndarray, item_ids: jnp.ndarray,
                  item_mask: jnp.ndarray, codes: jnp.ndarray,
                  shards: int, k: int = 1):
    """Pad item-axis arrays so every shard holds >= k rows and rows divide.

    Padding rows are dead: zero vectors, ``item_ids == -1``, mask False,
    zero codes — the scans force their scores to ``-inf`` (or their Hamming
    distance to +BIG), so they can never enter a top-k that a real row could
    occupy. No-op when the row count already divides and covers ``k``.
    """
    n = items.shape[0]
    rows_per = max(-(-n // shards), k)
    n_pad = rows_per * shards
    if n_pad == n:
        return items, item_ids, item_mask, codes
    pad = n_pad - n
    return (jnp.concatenate([items, jnp.zeros((pad,) + items.shape[1:],
                                              items.dtype)]),
            jnp.concatenate([item_ids,
                             jnp.full((pad,), -1, item_ids.dtype)]),
            jnp.concatenate([item_mask, jnp.zeros((pad,), bool)]),
            jnp.concatenate([codes, jnp.zeros((pad,) + codes.shape[1:],
                                              codes.dtype)]))


def index_specs(index: _sah.SAHIndex, policy: ShardingPolicy):
    """PartitionSpec pytree for a SAHIndex: user/block rows over every mesh
    axis, item side replicated. The index must already be padded to a
    block count that divides the mesh (``pad_index``)."""
    axes = tuple(policy.mesh.axis_names)
    specs = jax.tree.map(lambda _: P(), index)
    row = {f: P(axes, *([None] * (getattr(index, f).ndim - 1)))
           for f in _USER_AXIS_FIELDS + _BLOCK_AXIS_FIELDS}
    return specs._replace(**row)


def shard_index(index: _sah.SAHIndex, policy: ShardingPolicy
                ) -> _sah.SAHIndex:
    """Lay the index out for the mesh: user/block rows sharded, rest
    replicated. Pads the block axis first when it does not divide the
    device count (``pad_index``). No-op without a mesh."""
    if policy.mesh is None:
        return index
    index = pad_index(index, n_shards(policy))
    specs = index_specs(index, policy)
    shardings = jax.tree.map(lambda s: NamedSharding(policy.mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return jax.device_put(index, shardings)


def rkmips_batch(index: _sah.SAHIndex, queries: jnp.ndarray, k: int,
                 policy: ShardingPolicy, *, n_cand: int = 64,
                 scan: str = "sketch", chunk: int = 256,
                 tie_eps: float = 0.0, scan_precision: str = "f32",
                 delta_items: jnp.ndarray | None = None,
                 delta_mask: jnp.ndarray | None = None,
                 delta_qitems: jnp.ndarray | None = None,
                 delta_qscale: jnp.ndarray | None = None,
                 scan_budget=0):
    """Sharded Algorithm 5 over a query batch (one trace per batch shape).

    Returns (pred (nq, m_pad) bool in global leaf order, QueryStats with
    per-query counters summed over shards). m_pad reflects block padding
    when the block count does not divide the mesh; ``pad_index`` rows are
    masked, so ``predictions_to_original`` strips them. Without a mesh this
    is exactly ``core/sah.py::rkmips_batch``.

    The shard_map body is the raw batched plan/execute driver on the
    shard's user slice: the plan's lax.map holds only dense per-query math
    and the execute phase is one flat while_loop, so — unlike the retired
    per-query drivers (nested jit / scan-of-while, the jax 0.4.x
    miscompile, DESIGN.md SS9) — the body traces once at any nq. The
    shard-local work queues are what make this load-balanced: a shard
    whose users die early for one query spends its chunks on the other
    queries' survivors instead of idling.

    delta_items/delta_mask: optional staged-insert buffer (DESIGN.md SS10),
    replicated across shards — each shard counts its own user rows against
    the full buffer ((m_local, cap) products, no collective), so the psum'd
    counters and gathered predictions match the single-device delta path
    bitwise. delta_qitems/delta_qscale (the buffer's int8 twin, consumed
    under ``scan_precision == "int8"``) replicate the same way.

    scan_budget: the traced per-query tile cap (``rkmips_execute_impl``).
    On a mesh each shard enforces it against its OWN charged tile count —
    the cap bounds the slowest shard's walk, which is what bounds the
    dispatch's wall time — and the psum'd ``truncated`` stat flags a query
    any shard truncated.
    """
    budget = jnp.asarray(scan_budget, jnp.int32)
    if policy.mesh is None:
        return _sah.rkmips_batch(index, queries, k, n_cand=n_cand,
                                 scan=scan, chunk=chunk, tie_eps=tie_eps,
                                 scan_precision=scan_precision,
                                 delta_items=delta_items,
                                 delta_mask=delta_mask,
                                 delta_qitems=delta_qitems,
                                 delta_qscale=delta_qscale,
                                 scan_budget=budget)
    index = pad_index(index, n_shards(policy))
    axes = tuple(policy.mesh.axis_names)
    specs = index_specs(index, policy)
    if scan_precision != "int8":
        delta_qitems = delta_qscale = None
    has_delta = delta_items is not None
    has_qdelta = has_delta and delta_qitems is not None

    def local(idx_l: _sah.SAHIndex, qs: jnp.ndarray, bgt, *delta):
        d_items = d_mask = d_qitems = d_qscale = None
        if has_qdelta:
            d_items, d_mask, d_qitems, d_qscale = delta
        elif has_delta:
            d_items, d_mask = delta
        pred_l, stats_l = _sah.rkmips_batch_impl(
            idx_l, qs, k, n_cand=n_cand, scan=scan, chunk=chunk,
            tie_eps=tie_eps, scan_precision=scan_precision,
            delta_items=d_items, delta_mask=d_mask,
            delta_qitems=d_qitems, delta_qscale=d_qscale,
            scan_budget=bgt)
        pred = jax.lax.all_gather(pred_l, axes, axis=1, tiled=True)
        stats = jax.tree.map(lambda s: jax.lax.psum(s, axes), stats_l)
        return pred, stats

    extras = ()
    extra_specs = ()
    if has_qdelta:
        extras = (delta_items, delta_mask, delta_qitems, delta_qscale)
        extra_specs = (P(), P(), P(), P())
    elif has_delta:
        extras = (delta_items, delta_mask)
        extra_specs = (P(), P())
    return jax.shard_map(local, mesh=policy.mesh,
                         in_specs=(specs, P(), P()) + extra_specs,
                         out_specs=(P(), P()),
                         check_vma=False)(index, queries, budget, *extras)


def _flat_candidates(items, item_ids, item_mask, codes, ucodes, queries,
                     k: int, n_cand: int, scan: str):
    """One-pass scan over a row slab: sketch (Hamming top-n_cand + exact
    re-rank) or exact (dense IPs), then top-k. Returns (vals (Q, k),
    ids (Q, k) original item rows).

    The f32 work maps over queries (``lax.map``) instead of batching the
    contraction across them: XLA lowers a batched contraction differently
    at different Q, so a batched expression's per-row results drift in
    the last ulp across batch shapes — which would break the serving
    contract that a bucket-padded dispatch (any ladder rung, DESIGN.md
    SS14) is bitwise equal to the full-batch flush. The per-query body is
    shape-identical at every Q, so every executable computes identical
    rows; the N-axis work inside each step stays fully vectorized, and Q
    is a micro-batch on the serving path.
    """
    if scan == "exact":
        def one_exact(q):
            ips = jnp.where(item_mask, items @ q, _NEG)
            vals, pos = jax.lax.top_k(ips, k)
            return vals, jnp.take(item_ids, pos)
        return jax.lax.map(one_exact, queries)

    def one_sketch(args):
        uc, q = args
        dist = kops.hamming_scores(uc[None], codes)[0]    # (N,)
        dist = jnp.where(item_mask, dist, _BIG_HAMMING)
        _, cand = jax.lax.top_k(-dist, n_cand)            # (n_cand,)
        ips = jnp.take(items, cand, axis=0) @ q
        ips = jnp.where(jnp.take(item_mask, cand), ips, _NEG)
        vals, pos = jax.lax.top_k(ips, k)
        return vals, jnp.take(jnp.take(item_ids, cand), pos)
    return jax.lax.map(one_sketch, (ucodes, queries))


def kmips_flat_arrays(items: jnp.ndarray, item_ids: jnp.ndarray,
                      item_mask: jnp.ndarray, codes: jnp.ndarray,
                      ucodes: jnp.ndarray, queries: jnp.ndarray, k: int,
                      policy: ShardingPolicy, *, n_cand: int = 64,
                      scan: str = "sketch"):
    """``kmips_flat`` on raw row arrays (the serving-stack entry point).

    items (N, d), item_ids (N,) int32 original rows (-1 padding), item_mask
    (N,) bool, codes (N, W) uint32 sketches, ucodes (Q, W) query sketches,
    queries (Q, d) -> (vals (Q, k), ids (Q, k)). Any N shards over any mesh:
    rows are padded to the next multiple of the device count with dead rows
    (``pad_item_rows``) before the shard_map. Per-query results are
    independent of batching, so micro-batched serving dispatch
    (engine/serving.py) is bitwise equal to a one-shot batch.
    """
    if policy.mesh is None:
        n_c = min(max(n_cand, k), items.shape[0])
        return _flat_candidates(items, item_ids, item_mask, codes, ucodes,
                                queries, k, n_c, scan)

    items, item_ids, item_mask, codes = pad_item_rows(
        items, item_ids, item_mask, codes, n_shards(policy), k)
    axes = tuple(policy.mesh.axis_names)

    def local(items_l, ids_l, mask_l, codes_l, uc, qs):
        vals_l, gids_l = _flat_candidates(items_l, ids_l, mask_l, codes_l,
                                          uc, qs, k,
                                          min(max(n_cand, k),
                                              items_l.shape[0]), scan)
        vals_all = jax.lax.all_gather(vals_l, axes, axis=1, tiled=True)
        gids_all = jax.lax.all_gather(gids_l, axes, axis=1, tiled=True)
        best, pos = jax.lax.top_k(vals_all, k)
        return best, jnp.take_along_axis(gids_all, pos, axis=-1)

    return jax.shard_map(
        local, mesh=policy.mesh,
        in_specs=(P(axes, None), P(axes), P(axes), P(axes, None), P(), P()),
        out_specs=(P(), P()), check_vma=False,
    )(items, item_ids, item_mask, codes, ucodes, queries)


def kmips_flat(index: _alsh.SAALSHIndex, queries: jnp.ndarray, k: int,
               policy: ShardingPolicy, *, n_cand: int = 64,
               scan: str = "sketch"):
    """Single-pass kMIPS, sharded over item rows.

    queries (Q, d) -> (vals (Q, k) descending, ids (Q, k) original item
    rows). scan="sketch" Hamming-ranks then re-ranks ``n_cand`` candidates
    **per shard** (``n_cand >=`` the local row count makes it exact);
    scan="exact" skips the sketch and re-ranks every row. The mesh=None
    branch is the single-device oracle of the shard_map body (exercised by
    tests/test_engine.py); the engine's unsharded kmips uses the tiled
    early-terminating ``kmips_topk`` instead. Row counts that do not divide
    the mesh are padded with dead rows (``pad_item_rows``).
    """
    ucodes = _alsh.user_codes(index, queries)
    return kmips_flat_arrays(index.items, index.item_ids, index.item_mask,
                             index.codes, ucodes, queries, k, policy,
                             n_cand=n_cand, scan=scan)
