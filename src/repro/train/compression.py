"""int8 error-feedback gradient compression (1-bit-Adam-family trick).

Wraps an optimizer: before the update, each gradient leaf is quantized to
int8 with a per-leaf scale; the quantization error is accumulated into a
residual buffer and added back the next step (error feedback keeps the
compressed SGD/Adam convergent -- Seide et al. 2014, Tang et al. 2021).

Under pjit the gradients are already summed by the time user code sees them,
so the practical deployment is DP-group all-reduce of int8 payloads via
shard_map; `compressed_psum` below is that primitive (quantize -> psum int32
-> dequantize), used by the trainer when `compress_grads=True`. The optimizer
wrapper provides the error-feedback residual in either case. 4x fewer bytes
on the wire than f32 (2x vs bf16).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.train.optimizer import Optimizer


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x f32 -> (q int8, scale f32 scalar). scale maps 127 -> max|x|."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """psum of an int8-quantized tensor over `axis_name` (inside shard_map).

    int8 payloads are accumulated in int32 (no overflow for <= 2^24 ranks);
    scales are psum-maxed... scales are averaged consistently by summing the
    dequantized contributions: sum_i q_i * s_i = psum(q * 1) per-shard scale
    applied before the reduce would lose the compression, so each shard sends
    (q int8, s f32) and the sum uses a shared max-scale:
        s_max = pmax(s); q' = round(x / s_max); psum(q') * s_max.
    """
    amax = jnp.max(jnp.abs(x))
    s_max = jax.lax.pmax(jnp.maximum(amax, 1e-12) / 127.0, axis_name)
    q = jnp.clip(jnp.round(x / s_max), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * s_max


def error_feedback(inner: Optimizer) -> Optimizer:
    """Error-feedback int8 compression around an optimizer's gradient input."""

    def init(params):
        return {
            "residual": jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "inner": inner.init(params),
        }

    def update(grads, state, params):
        def compress(g, r):
            g = g.astype(jnp.float32) + r
            q, s = quantize_int8(g)
            deq = dequantize_int8(q, s)
            return deq, g - deq

        out = jax.tree.map(compress, grads, state["residual"])
        comp = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda t: isinstance(t, tuple))
        resid = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        updates, inner_state = inner.update(comp, state["inner"], params)
        return updates, {"residual": resid, "inner": inner_state}

    return Optimizer(init, update)
