"""Fig. 1 + Fig. 2 + Table 1: RkMIPS query time / F1 vs k, ablation grid,
indexing time -- for SAH, SA-Simpfer, H2-Cone, H2-Simpfer, Simpfer.

Raw H2-ALSH (no user pruning at all) is omitted: the paper shows it 2-3
orders of magnitude slower than every pruned method (Fig. 1); our grid keeps
the informative frontier. All other methods are exact configurations of the
same engine (DESIGN.md SS3), so the comparison isolates exactly the paper's
two contributions (SAT vs QNF; cone vs norm blocking).
"""

from __future__ import annotations

from benchmarks import common


def run(n=8192, m=16384, d=64, nq=16, ks=(1, 5, 10, 20, 30, 40, 50)):
    wl = common.make_workload("nmf", n, m, d, nq, ks)
    rows = []
    for method in common.METHODS:
        eng, t_build = common.build_method(wl, method)
        rows.append(common.fmt_row(
            f"table1/index_time/{method}", t_build * 1e6,
            f"n={n};m={m}"))
        for k in ks:
            dt, f1, stats = common.run_method(wl, eng, k)
            rows.append(common.fmt_row(
                f"fig1/query/{method}/k={k}", dt * 1e6,
                f"f1={f1:.3f};scanned={int(stats.n_scan.mean())}"))
    return rows
