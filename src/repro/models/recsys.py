"""RecSys models: DeepFM, xDeepFM (CIN), DIN, and two-tower retrieval.

All share the sharded EmbeddingBag substrate (models/embedding.py). The
feature-interaction ops follow the cited papers:

  DeepFM  (Guo et al. 2017):   logit = linear + FM2 + MLP(concat(emb))
          FM2 = 0.5 * sum_d[(sum_f v)^2 - sum_f v^2]
  xDeepFM (Lian et al. 2018):  CIN feature maps
          X^{k+1}_{h,d} = sum_{i,j} W^k_{h,i,j} X^k_{i,d} X^0_{j,d};
          logit = linear + w . concat_k(sum_d X^k) + MLP
  DIN     (Zhou et al. 2018):  target attention over the behaviour sequence
          a_t = MLP([h_t, e_q, h_t - e_q, h_t * e_q]); pooled = sum a_t h_t
  two-tower (Yi et al. RecSys'19): MLP towers -> dot; trained with in-batch
          sampled softmax; candidate scoring is MIPS, which is where the
          paper's SAH/SA-ALSH index plugs in (launch/serve.py).

Two-tower reverse direction ("which users would retrieve this item") is
literally the paper's RkMIPS problem -- examples/reverse_recommend.py.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.policy import NO_SHARDING, ShardingPolicy
from repro.models import embedding as emb_lib


def _mlp_init(key, dims: tuple[int, ...], dtype) -> list[dict]:
    layers = []
    for i in range(len(dims) - 1):
        k1, key = jax.random.split(key)
        layers.append({
            "w": (jax.random.normal(k1, (dims[i], dims[i + 1]))
                  * dims[i] ** -0.5).astype(dtype),
            "b": jnp.zeros((dims[i + 1],), dtype),
        })
    return layers


def _mlp_apply(layers: list[dict], x: jnp.ndarray,
               final_act: bool = False) -> jnp.ndarray:
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def bce_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    z = logits.astype(jnp.float32)
    y = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


# ---------------------------------------------------------------------------
# DeepFM / xDeepFM (Criteo-style: n_fields single-valued categorical ids)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CTRConfig:
    name: str
    embedding: emb_lib.EmbeddingConfig
    mlp_dims: tuple[int, ...]            # hidden dims; input/output added
    interaction: str                     # "fm" | "cin"
    cin_layers: tuple[int, ...] = ()
    dtype: Any = jnp.float32


def init_ctr_params(key: jax.Array, cfg: CTRConfig, *,
                    table_pad: int = 1) -> dict:
    ke, kl, km, kc, kw = jax.random.split(key, 5)
    f, d = cfg.embedding.n_fields, cfg.embedding.dim
    p = {
        "table": emb_lib.init_table(ke, cfg.embedding, pad_to=table_pad),
        "linear": (jax.random.normal(
            kl, (cfg.embedding.total_rows,)) * 0.01).astype(cfg.dtype),
        "mlp": _mlp_init(km, (f * d,) + cfg.mlp_dims + (1,), cfg.dtype),
    }
    if cfg.interaction == "cin":
        sizes = (f,) + cfg.cin_layers
        p["cin"] = [
            (jax.random.normal(jax.random.fold_in(kc, i),
                               (sizes[i + 1], sizes[i], f))
             * (sizes[i] * f) ** -0.5).astype(cfg.dtype)
            for i in range(len(cfg.cin_layers))]
        p["cin_out"] = (jax.random.normal(kw, (sum(cfg.cin_layers),))
                        * 0.01).astype(cfg.dtype)
    return p


def _cin(x0: jnp.ndarray, weights: list[jnp.ndarray]) -> jnp.ndarray:
    """Compressed Interaction Network. x0 (B, F, D) -> (B, sum(H_k))."""
    xk = x0
    pooled = []
    for w in weights:
        # (B, H_{k+1}, D) = sum_{i,j} w[h,i,j] * xk[b,i,d] * x0[b,j,d]
        xk = jnp.einsum("bid,bjd,hij->bhd", xk, x0, w)
        pooled.append(jnp.sum(xk, axis=-1))            # (B, H)
    return jnp.concatenate(pooled, axis=-1)


def ctr_forward(params: dict, batch: dict, cfg: CTRConfig,
                policy: ShardingPolicy = NO_SHARDING) -> jnp.ndarray:
    """batch = {"sparse": (B, n_fields) int32} -> logits (B,)."""
    rows = emb_lib.flatten_ids(batch["sparse"], cfg.embedding)   # (B, F)
    v = emb_lib.embedding_bag(params["table"], rows, policy)     # (B, F, D)
    b, f, d = v.shape

    lin = jnp.sum(jnp.take(params["linear"], rows), axis=-1)     # (B,)
    logit = lin
    if cfg.interaction == "fm":
        s = jnp.sum(v, axis=1)                                   # (B, D)
        fm = 0.5 * jnp.sum(s * s - jnp.sum(v * v, axis=1), axis=-1)
        logit = logit + fm
    elif cfg.interaction == "cin":
        cin = _cin(v, params["cin"])                             # (B, sumH)
        logit = logit + cin @ params["cin_out"]
    deep = _mlp_apply(params["mlp"], v.reshape(b, f * d))[:, 0]
    return logit + deep


def ctr_loss(params, batch, cfg: CTRConfig,
             policy: ShardingPolicy = NO_SHARDING):
    return bce_loss(ctr_forward(params, batch, cfg, policy), batch["label"])


# ---------------------------------------------------------------------------
# DIN
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str
    embedding: emb_lib.EmbeddingConfig   # field 0 = item vocab (hist+target)
    seq_len: int
    attn_mlp: tuple[int, ...]            # e.g. (80, 40)
    mlp_dims: tuple[int, ...]            # e.g. (200, 80)
    dtype: Any = jnp.float32


def init_din_params(key: jax.Array, cfg: DINConfig, *,
                    table_pad: int = 1) -> dict:
    ke, ka, km = jax.random.split(key, 3)
    d = cfg.embedding.dim
    n_profile = cfg.embedding.n_fields - 1
    return {
        "table": emb_lib.init_table(ke, cfg.embedding, pad_to=table_pad),
        "attn": _mlp_init(ka, (4 * d,) + cfg.attn_mlp + (1,), cfg.dtype),
        "mlp": _mlp_init(km, ((2 + n_profile) * d,) + cfg.mlp_dims + (1,),
                         cfg.dtype),
    }


def din_forward(params: dict, batch: dict, cfg: DINConfig,
                policy: ShardingPolicy = NO_SHARDING) -> jnp.ndarray:
    """batch = {"hist" (B,T), "hist_mask" (B,T), "target" (B,),
    "profile" (B, n_profile)} -> logits (B,)."""
    d = cfg.embedding.dim
    hist_rows = batch["hist"] + cfg.embedding.offsets[0]
    tgt_rows = batch["target"] + cfg.embedding.offsets[0]
    h = emb_lib.embedding_bag(params["table"], hist_rows, policy)   # (B,T,D)
    e = emb_lib.embedding_bag(params["table"], tgt_rows, policy)    # (B,D)
    # profile fields use table fields 1..n (field 0 is the item vocab)
    prof_rows = batch["profile"] + jnp.asarray(cfg.embedding.offsets[1:])
    prof = emb_lib.embedding_bag(params["table"], prof_rows, policy)

    eq = jnp.broadcast_to(e[:, None, :], h.shape)
    a_in = jnp.concatenate([h, eq, h - eq, h * eq], axis=-1)        # (B,T,4D)
    scores = _mlp_apply(params["attn"], a_in)[..., 0]               # (B,T)
    scores = jnp.where(batch["hist_mask"], scores, -1e30)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(h.dtype)
    pooled = jnp.einsum("bt,btd->bd", w, h)

    feats = jnp.concatenate(
        [pooled, e, prof.reshape(prof.shape[0], -1)], axis=-1)
    return _mlp_apply(params["mlp"], feats)[:, 0]


def din_loss(params, batch, cfg: DINConfig,
             policy: ShardingPolicy = NO_SHARDING):
    return bce_loss(din_forward(params, batch, cfg, policy), batch["label"])


# ---------------------------------------------------------------------------
# Two-tower retrieval
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str
    user_embedding: emb_lib.EmbeddingConfig
    item_embedding: emb_lib.EmbeddingConfig
    tower_dims: tuple[int, ...] = (1024, 512, 256)
    out_dim: int = 256
    dtype: Any = jnp.float32


def init_twotower_params(key: jax.Array, cfg: TwoTowerConfig, *,
                         table_pad: int = 1) -> dict:
    ku, ki, k1, k2 = jax.random.split(key, 4)
    du = cfg.user_embedding.n_fields * cfg.user_embedding.dim
    di = cfg.item_embedding.n_fields * cfg.item_embedding.dim
    return {
        "user_table": emb_lib.init_table(ku, cfg.user_embedding,
                                         pad_to=table_pad),
        "item_table": emb_lib.init_table(ki, cfg.item_embedding,
                                         pad_to=table_pad),
        "user_mlp": _mlp_init(k1, (du,) + cfg.tower_dims + (cfg.out_dim,),
                              cfg.dtype),
        "item_mlp": _mlp_init(k2, (di,) + cfg.tower_dims + (cfg.out_dim,),
                              cfg.dtype),
    }


def user_tower(params, user_feats: jnp.ndarray, cfg: TwoTowerConfig,
               policy: ShardingPolicy = NO_SHARDING) -> jnp.ndarray:
    rows = emb_lib.flatten_ids(user_feats, cfg.user_embedding)
    v = emb_lib.embedding_bag(params["user_table"], rows, policy)
    v = v.reshape(v.shape[0], -1)
    return _mlp_apply(params["user_mlp"], v)


def item_tower(params, item_feats: jnp.ndarray, cfg: TwoTowerConfig,
               policy: ShardingPolicy = NO_SHARDING) -> jnp.ndarray:
    rows = emb_lib.flatten_ids(item_feats, cfg.item_embedding)
    v = emb_lib.embedding_bag(params["item_table"], rows, policy)
    v = v.reshape(v.shape[0], -1)
    return _mlp_apply(params["item_mlp"], v)


def twotower_loss(params, batch: dict, cfg: TwoTowerConfig,
                  policy: ShardingPolicy = NO_SHARDING) -> jnp.ndarray:
    """In-batch sampled softmax with logQ correction.

    batch = {"user_feats" (B,Fu), "item_feats" (B,Fi), "log_q" (B,)}.
    Row i's positive is item i; all other rows are negatives.
    """
    u = user_tower(params, batch["user_feats"], cfg, policy)
    v = item_tower(params, batch["item_feats"], cfg, policy)
    logits = (u @ v.T).astype(jnp.float32)              # (B, B)
    logits = logits - batch["log_q"][None, :]           # logQ correction
    labels = jnp.arange(u.shape[0])
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def retrieval_scores(user_vec: jnp.ndarray,
                     cand_vecs: jnp.ndarray) -> jnp.ndarray:
    """(B, D) x (N, D) -> (B, N) brute-force scores (the exact baseline;
    the SAH-indexed path lives in launch/serve.py)."""
    return user_vec @ cand_vecs.T
