"""Pallas kernel correctness: interpret-mode vs jnp oracle over shape/dtype
sweeps (per-kernel allclose, exact equality for integer outputs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention as fa
from repro.kernels import hamming_scan, ip_topk, ref, srp_hash
from repro.kernels.ops import _merge_topk


def _codes(key, n, w):
    return jax.random.randint(key, (n, w), 0, 2**31 - 1,
                              dtype=jnp.int32).astype(jnp.uint32)


@pytest.mark.parametrize("q,n,w,bq,bn", [
    (64, 256, 4, 32, 128),
    (128, 512, 8, 128, 512),
    (32, 1024, 1, 32, 256),
    (256, 256, 16, 64, 64),
])
def test_hamming_matches_ref(q, n, w, bq, bn):
    k1, k2 = jax.random.split(jax.random.PRNGKey(q + n + w))
    qc, ic = _codes(k1, q, w), _codes(k2, n, w)
    out = hamming_scan.hamming_scores(qc, ic, block_q=bq, block_n=bn,
                                      interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.hamming_scores(qc, ic)))


def test_hamming_identity_and_complement():
    k = jax.random.PRNGKey(0)
    c = _codes(k, 64, 4)
    d = hamming_scan.hamming_scores(c, c, block_q=64, block_n=64,
                                    interpret=True)
    assert (np.diag(np.asarray(d)) == 0).all()
    comp = jnp.bitwise_xor(c, jnp.uint32(0xFFFFFFFF))
    d2 = hamming_scan.hamming_scores(c, comp, block_q=64, block_n=64,
                                     interpret=True)
    assert (np.diag(np.asarray(d2)) == 32 * 4).all()


@pytest.mark.parametrize("n,d,bits,bn", [
    (256, 64, 128, 128),
    (512, 101, 256, 256),
    (128, 17, 32, 64),
])
def test_srp_hash_matches_ref(n, d, bits, bn):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n + d))
    x = jax.random.normal(k1, (n, d))
    proj = jax.random.normal(k2, (d, bits))
    out = srp_hash.srp_hash(x, proj, block_n=min(bn, n), interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.srp_hash(x, proj)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_srp_hash_dtypes(dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(5))
    x = jax.random.normal(k1, (128, 32)).astype(dtype)
    proj = jax.random.normal(k2, (32, 64)).astype(dtype)
    out = srp_hash.srp_hash(x.astype(jnp.float32),
                            proj.astype(jnp.float32), block_n=128,
                            interpret=True)
    assert out.dtype == jnp.uint32


@pytest.mark.parametrize("q,n,d,k,bq,bn", [
    (8, 1024, 32, 8, 8, 256),
    (16, 2048, 64, 32, 16, 512),
    (4, 512, 128, 100, 4, 512),
])
def test_ip_topk_matches_ref(q, n, d, k, bq, bn):
    k1, k2 = jax.random.split(jax.random.PRNGKey(q * n))
    queries = jax.random.normal(k1, (q, d))
    items = jax.random.normal(k2, (n, d))
    vals, ids = ip_topk.ip_topk_tiles(queries, items, k, block_q=bq,
                                      block_n=bn, interpret=True)
    bv, bi = _merge_topk(vals, ids, k)
    rv, ri = ref.ip_topk(queries, items, k)
    np.testing.assert_allclose(np.asarray(bv), np.asarray(rv), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(bi), np.asarray(ri))


@pytest.mark.parametrize("b,h,s,dh,bq,bk,causal", [
    (2, 3, 128, 32, 32, 32, True),
    (1, 2, 256, 64, 64, 128, True),
    (2, 2, 64, 16, 64, 16, False),
    (1, 1, 128, 128, 128, 32, True),
])
def test_flash_attention_matches_ref(b, h, s, dh, bq, bk, causal):
    key = jax.random.PRNGKey(b * s + dh)
    q = jax.random.normal(key, (b, h, s, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, h, s, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, h, s, dh))
    out = fa.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                             interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=5e-5)


def test_flash_attention_bf16():
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (1, 2, 64, 32)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (1, 2, 64, 32)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (1, 2, 64, 32)).astype(jnp.bfloat16)
    out = fa.flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention(q, k, v)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=3e-2)


def test_ip_topk_with_duplicate_scores():
    # tie-breaking: top_k prefers lower index; the tiled kernel must agree
    queries = jnp.ones((4, 16))
    items = jnp.concatenate([jnp.ones((64, 16)), jnp.zeros((64, 16))])
    vals, ids = ip_topk.ip_topk_tiles(queries, items, 8, block_q=4,
                                      block_n=32, interpret=True)
    bv, bi = _merge_topk(vals, ids, 8)
    rv, ri = ref.ip_topk(queries, items, 8)
    np.testing.assert_allclose(np.asarray(bv), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(bi), np.asarray(ri))
