"""Decoder-only LM transformer: dense (GQA/RoPE/qk-norm/QKV-bias/SwiGLU) and
MoE variants, scan-over-layers with configurable remat, train / prefill /
decode entry points.

Parameters are plain pytrees with a leading (L,) layer axis so the whole stack
is one lax.scan: HLO stays small (compile time at 512 devices) and XLA's
latency-hiding scheduler overlaps layer-i collectives with layer-i+1 compute.

Sharding is injected through a ShardingPolicy (repro/dist/policy.py); with
mesh=None the model is ordinary single-device JAX (smoke tests).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist import compat as dist_compat
from repro.dist.policy import NO_SHARDING, ShardingPolicy
from repro.models import attention as attn
from repro.models import moe as moe_lib


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    moe: moe_lib.MoEConfig | None = None
    dtype: Any = jnp.bfloat16
    attn_chunk: int = 512
    attn_impl: str = "chunked"   # "chunked" (pure JAX, dry-run path) |
    #                              "flash" (fused Pallas kernel: keeps score
    #                              tiles in VMEM; the TPU deployment path --
    #                              cannot lower in the CPU dry-run)
    remat: str = "full"          # "full" | "none"
    max_seq: int = 4096          # decode cache length
    aux_loss_weight: float = 0.01
    scan_layers: bool = True     # False: python-unrolled (cost analysis mode:
    #                              XLA cost_analysis counts a while body once,
    #                              so the dry-run extrapolates from unrolled
    #                              L=1/L=2 lowerings; see launch/dryrun.py)

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else (
            self.d_model // self.n_heads)

    @property
    def n_params(self) -> int:
        """Total parameter count (embedding + head included)."""
        d, hd = self.d_model, self.head_dim
        attn_p = d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2
        if self.moe is not None:
            ffn = (d * self.moe.n_experts
                   + 3 * self.moe.n_experts * d * self.moe.d_ff_expert)
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn_p + ffn + 2 * d
        return (self.n_layers * per_layer + 2 * self.vocab * d + d)

    @property
    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.n_params
        d = self.d_model
        dense = self.n_params - self.n_layers * (
            3 * self.moe.n_experts * d * self.moe.d_ff_expert)
        return dense + self.n_layers * 3 * self.moe.top_k * d * \
            self.moe.d_ff_expert


def init_params(key: jax.Array, cfg: LMConfig) -> dict:
    """Stacked-layer parameter pytree."""
    d, hd = cfg.d_model, cfg.head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    keys = jax.random.split(key, 8)

    def norm(k, *shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(cfg.dtype)

    def layer_init(k):
        ks = jax.random.split(k, 8)
        p = {
            "wq": norm(ks[0], d, nh * hd, scale=d ** -0.5),
            "wk": norm(ks[1], d, nkv * hd, scale=d ** -0.5),
            "wv": norm(ks[2], d, nkv * hd, scale=d ** -0.5),
            "wo": norm(ks[3], nh * hd, d, scale=(nh * hd) ** -0.5),
            "ln1": jnp.ones((d,), cfg.dtype),
            "ln2": jnp.ones((d,), cfg.dtype),
        }
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((nh * hd,), cfg.dtype)
            p["bk"] = jnp.zeros((nkv * hd,), cfg.dtype)
            p["bv"] = jnp.zeros((nkv * hd,), cfg.dtype)
        if cfg.qk_norm:
            p["q_norm"] = jnp.ones((hd,), cfg.dtype)
            p["k_norm"] = jnp.ones((hd,), cfg.dtype)
        if cfg.moe is not None:
            p["moe"] = moe_lib.init_moe_params(ks[4], d, cfg.moe, cfg.dtype)
        else:
            p["w_in"] = norm(ks[4], d, cfg.d_ff, scale=d ** -0.5)
            p["w_gate"] = norm(ks[5], d, cfg.d_ff, scale=d ** -0.5)
            p["w_out"] = norm(ks[6], cfg.d_ff, d, scale=cfg.d_ff ** -0.5)
        return p

    layers = jax.vmap(layer_init)(jax.random.split(keys[0], cfg.n_layers))
    return {
        "embed": norm(keys[1], cfg.vocab, d, scale=1.0),
        "head": norm(keys[2], d, cfg.vocab, scale=d ** -0.5),
        "final_norm": jnp.ones((d,), cfg.dtype),
        "layers": layers,
    }


def param_specs(cfg: LMConfig, policy: ShardingPolicy) -> dict:
    """PartitionSpec pytree matching init_params output."""
    r = policy.rules
    layer = {
        "wq": r["p_attn_in"], "wk": r["p_attn_in"], "wv": r["p_attn_in"],
        "wo": r["p_attn_out"], "ln1": r["p_norm"], "ln2": r["p_norm"],
    }
    if cfg.qkv_bias:
        bias = jax.sharding.PartitionSpec(None, None)
        layer.update({"bq": bias, "bk": bias, "bv": bias})
    if cfg.qk_norm:
        layer.update({"q_norm": r["p_norm"], "k_norm": r["p_norm"]})
    if cfg.moe is not None:
        layer["moe"] = {
            "router": r["p_router"],
            "w_in": r["p_expert_in"], "w_gate": r["p_expert_in"],
            "w_out": r["p_expert_out"],
        }
    else:
        layer.update({"w_in": r["p_mlp_in"], "w_gate": r["p_mlp_in"],
                      "w_out": r["p_mlp_out"]})
    return {
        "embed": r["p_embed"],
        "head": r["p_head"],
        "final_norm": jax.sharding.PartitionSpec(None),
        "layers": layer,
    }


def _rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(
        x.dtype) * scale


def _rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x (..., S, Dh), positions (S,) -> rotated."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # (S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _project_qkv(x, p, cfg: LMConfig, positions):
    """x (B, S, D) -> q (B,H,S,Dh), k/v (B,Hkv,S,Dh) with RoPE applied."""
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = _rms_norm(q, p["q_norm"])
        k = _rms_norm(k, p["k_norm"])
    q = _rope(q, positions, cfg.rope_theta)
    k = _rope(k, positions, cfg.rope_theta)
    return q, k, v


def _layer(x, p, cfg: LMConfig, policy: ShardingPolicy, positions):
    """One transformer block. x (B, S, D) -> (x', aux_loss, (k, v))."""
    h = _rms_norm(x, p["ln1"])
    # SP->TP boundary: gather the sequence axis once here (one all-gather);
    # projections then emit head-sharded q/k/v natively instead of GSPMD
    # discovering the transition mid-chain (which degenerates to full remat).
    h = policy.constrain(h, "act_attn_in")
    q, k, v = _project_qkv(h, p, cfg, positions)
    q = policy.constrain(q, "act_bhsd")
    # Repeat KV to full head count and pin the head-sharded layout: without
    # the constraint GSPMD propagates the sequence-parallel sharding into the
    # repeat broadcast and falls back to full rematerialization at the SP->TP
    # boundary (seen as spmd_partitioner 'Involuntary full remat' warnings).
    kr = attn.repeat_kv(k, cfg.n_heads // cfg.n_kv_heads)
    vr = attn.repeat_kv(v, cfg.n_heads // cfg.n_kv_heads)
    kr = policy.constrain(kr, "act_bhsd")
    vr = policy.constrain(vr, "act_bhsd")
    if cfg.attn_impl == "flash":
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, kr, vr, causal=True)
    else:
        o = attn.chunked_attention(q, kr, vr, chunk=min(cfg.attn_chunk,
                                                        x.shape[1]))
    b, s, _ = x.shape
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    x = x + (o @ p["wo"]).astype(x.dtype)
    x = policy.constrain(x, "act_btd")

    h = _rms_norm(x, p["ln2"])
    if cfg.moe is not None:
        f, aux = moe_lib.moe_ffn(h, p["moe"], cfg.moe, policy)
    else:
        gate = h @ p["w_gate"]
        up = h @ p["w_in"]
        gate = policy.constrain(gate, "act_btf")
        f = (jax.nn.silu(gate) * up) @ p["w_out"]
        aux = jnp.zeros((), jnp.float32)
    x = x + f.astype(x.dtype)
    x = policy.constrain(x, "act_btd")
    return x, aux, (k, v)


def forward(params, tokens: jnp.ndarray, cfg: LMConfig,
            policy: ShardingPolicy = NO_SHARDING, *,
            return_cache: bool = False):
    """tokens (B, S) int32 -> (hidden (B,S,D) post-final-norm, aux, cache).

    Returns hidden states, NOT logits: materializing (B, S, V) f32 logits is
    a multi-GiB allocation at vocab 152k; loss and serving project only what
    they need (chunked CE / last position).
    """
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = policy.constrain(x, "act_btd")
    positions = jnp.arange(s)

    def body(x, lp):
        # barrier: stops XLA folding the rms-norm f32 upcast into the
        # scan-saved carry buffer (which would store residuals at 2x bytes);
        # the compat wrapper keeps it differentiable on jax 0.4.x
        x = dist_compat.optimization_barrier(x)
        x2, aux, kv = _layer(x, lp, cfg, policy, positions)
        return x2, (aux, kv if return_cache else None)

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, (auxes, caches) = jax.lax.scan(body, x, params["layers"])
        aux_mean = jnp.mean(auxes)
    else:
        aux_sum = jnp.zeros((), jnp.float32)
        kvs = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, (aux, kv) = body(x, lp)
            aux_sum = aux_sum + aux
            if return_cache:
                kvs.append(kv)
        aux_mean = aux_sum / cfg.n_layers
        caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *kvs)
                  if return_cache else None)
    x = _rms_norm(x, params["final_norm"])
    return x, aux_mean, caches


def full_logits(params, hidden: jnp.ndarray, cfg: LMConfig,
                policy: ShardingPolicy = NO_SHARDING) -> jnp.ndarray:
    """(B, S, D) -> (B, S, V) f32. Small-vocab / test use only."""
    logits = (hidden @ params["head"]).astype(jnp.float32)
    return policy.constrain(logits, "logits")


def lm_loss(params, batch, cfg: LMConfig,
            policy: ShardingPolicy = NO_SHARDING, *,
            loss_chunk: int = 512):
    """batch = {"tokens": (B,S), "labels": (B,S)} -> scalar loss.

    Cross-entropy is computed in *batch* chunks under jax.checkpoint so the
    (bc, S, V) logits are transient in both passes -- at vocab 152k the
    unchunked logits would be GiBs of f32. Chunking over batch (not sequence)
    keeps every chunk aligned with the DP sharding; sequence chunks would
    straddle sequence-parallel shards and force SPMD full-rematerializations.
    loss_chunk: target tokens per (chunk x device); chunk count is derived
    and clamped to divide B.
    """
    hidden, aux, _ = forward(params, batch["tokens"], cfg, policy)
    b, s, d = hidden.shape
    labels = batch["labels"]
    n_chunks = 8 if (b % 8 == 0 and loss_chunk < s * b) else 1
    bc = b // n_chunks
    h_r = hidden.reshape(n_chunks, bc, s, d)
    y_r = labels.reshape(n_chunks, bc, s)

    def chunk_nll(carry, xs):
        # CE = logsumexp(logits) - <h, head[:, y]>. Gathering label columns
        # from the (D, V) head (D x tokens bytes) instead of take_along_axis
        # on the V-sharded (bc, S, V) logits avoids a logits-sized all-gather
        # + backward all-reduce per chunk (~40 GB/step at vocab 152k).
        h_c, y_c = xs
        # bf16 inputs + f32 accumulation: no f32 copy of h_c materializes
        logits = jnp.dot(h_c, params["head"],
                         preferred_element_type=jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)       # (bc, S)
        w_y = jnp.take(params["head"], y_c, axis=1)              # (D, bc, S)
        correct = jnp.einsum("bsd,dbs->bs", h_c, w_y,
                             preferred_element_type=jnp.float32)
        return carry + jnp.sum(lse - correct), None

    if n_chunks == 1:
        total, _ = chunk_nll(jnp.zeros((), jnp.float32), (h_r[0], y_r[0]))
    else:
        total, _ = jax.lax.scan(jax.checkpoint(chunk_nll),
                                jnp.zeros((), jnp.float32), (h_r, y_r))
    return total / (b * s) + cfg.aux_loss_weight * aux


def init_cache(cfg: LMConfig, batch: int, dtype=None) -> dict:
    """Decode KV cache: (L, B, Hkv, Smax, Dh) k & v + length scalar."""
    dtype = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, cfg.max_seq, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "length": jnp.zeros((), jnp.int32)}


def decode_step(params, cache: dict, tokens: jnp.ndarray, cfg: LMConfig,
                policy: ShardingPolicy = NO_SHARDING):
    """One decode step. tokens (B,) int32 -> (logits (B, V), new cache).

    The cache sequence axis may be sharded ('kv_cache' rule); the attention
    reductions then lower to the distributed flash-decode schedule
    (see models/attention.py).
    """
    b = tokens.shape[0]
    pos = cache["length"]
    x = jnp.take(params["embed"], tokens, axis=0)[:, None, :]   # (B, 1, D)
    positions = pos[None].astype(jnp.int32)

    def body(x, scanned):
        lp, kc, vc = scanned
        h = _rms_norm(x, lp["ln1"])
        q, k, v = _project_qkv(h, lp, cfg, positions)
        # Insert the new position into the cache.
        kc = jax.lax.dynamic_update_slice(kc, k, (0, 0, pos, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, 0, pos, 0))
        kc = policy.constrain(kc[None], "kv_cache")[0]
        vc = policy.constrain(vc[None], "kv_cache")[0]
        rep = cfg.n_heads // cfg.n_kv_heads
        o = attn.decode_attention(q[:, :, 0, :], attn.repeat_kv(kc, rep),
                                  attn.repeat_kv(vc, rep), pos + 1)
        x = x + (o.reshape(b, 1, -1) @ lp["wo"]).astype(x.dtype)
        h2 = _rms_norm(x, lp["ln2"])
        if cfg.moe is not None:
            f, _ = moe_lib.moe_ffn(h2, lp["moe"], cfg.moe, policy)
        else:
            f = (jax.nn.silu(h2 @ lp["w_gate"]) * (h2 @ lp["w_in"])
                 ) @ lp["w_out"]
        x = x + f.astype(x.dtype)
        return x, (kc, vc)

    if cfg.scan_layers:
        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
    else:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, (kc, vc) = body(x, (lp, cache["k"][i], cache["v"][i]))
            ks.append(kc)
            vs.append(vc)
        k_new, v_new = jnp.stack(ks), jnp.stack(vs)
    x = _rms_norm(x[:, 0, :], params["final_norm"])
    logits = (x @ params["head"]).astype(jnp.float32)
    logits = policy.constrain(logits[:, None, :], "logits")[:, 0, :]
    new_cache = {"k": k_new, "v": v_new, "length": pos + 1}
    return logits, new_cache


def prefill(params, tokens: jnp.ndarray, cfg: LMConfig,
            policy: ShardingPolicy = NO_SHARDING):
    """Prefill: full forward that also materializes the KV cache.

    Returns (last-position logits (B, V), cache dict).
    """
    b, s = tokens.shape
    hidden, _, caches = forward(params, tokens, cfg, policy,
                                return_cache=True)
    k, v = caches                                   # (L, B, Hkv, S, Dh)
    pad = cfg.max_seq - s
    if pad > 0:
        cfgp = [(0, 0)] * 3 + [(0, pad), (0, 0)]
        k, v = jnp.pad(k, cfgp), jnp.pad(v, cfgp)
    k = policy.constrain(k, "kv_cache")
    v = policy.constrain(v, "kv_cache")
    last = (hidden[:, -1, :] @ params["head"]).astype(jnp.float32)
    last = policy.constrain(last[:, None, :], "logits")[:, 0, :]
    return last, {"k": k, "v": v, "length": jnp.asarray(s, jnp.int32)}
