"""xdeepfm: 39 sparse fields, embed_dim=10, CIN 200-200-200, MLP 400-400.
[arXiv:1803.05170]"""

from repro.configs import base
from repro.configs.deepfm import VOCABS
from repro.models.embedding import EmbeddingConfig
from repro.models.recsys import CTRConfig


def make_config() -> CTRConfig:
    return CTRConfig(
        name="xdeepfm",
        embedding=EmbeddingConfig(vocab_sizes=VOCABS, dim=10),
        mlp_dims=(400, 400), interaction="cin",
        cin_layers=(200, 200, 200))


def make_smoke_config() -> CTRConfig:
    return CTRConfig(
        name="xdeepfm-smoke",
        embedding=EmbeddingConfig(vocab_sizes=(1000, 500, 200, 100), dim=8),
        mlp_dims=(32, 32), interaction="cin", cin_layers=(8, 8))


base.register(base.ArchSpec(
    arch_id="xdeepfm", family="recsys", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=base.RECSYS_SHAPES,
    source="arXiv:1803.05170",
    notes="CIN = explicit high-order feature interactions (einsum), the "
          "compute-dominant branch at large batch"))
