"""Pallas TPU kernel: fused causal flash attention (forward).

The roofline table (EXPERIMENTS.md SSRoofline) shows every LM train/prefill
cell memory-bound, dominated by attention-chunk HBM round-trips: the pure-JAX
chunked attention materializes each (block_q, block_k) score tile in HBM
between the QK matmul and the softmax/PV stages. This kernel keeps the tile
in VMEM across QK -> online-softmax -> PV, so HBM traffic per layer drops
from O(S^2/chunk * passes) score-tile bytes to just Q/K/V/O.

Grid: (batch*heads, q_blocks, k_blocks) with the k axis innermost
(sequential): the (m, l, acc) running stats live in VMEM scratch across the
k-block sweep and are flushed to the output on the last block. Causal
masking skips fully-masked tiles via pl.when.

VMEM at block_q=block_k=512, dh=128: q/k/v tiles 3*512*128*4 = 768 KB,
scores 512*512*4 = 1 MB, acc 256 KB -- well inside 16 MB.

Backward runs through the jnp reference (jax.custom_vjp with ref recompute):
the forward kernel is where the dry-run's dominant term lives; a fused
backward is the next iteration (EXPERIMENTS SSPerf next-levers).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, block_q: int, block_k: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (bq, dh)
        k = k_ref[0].astype(jnp.float32)                # (bk, dh)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal:
        # skip tiles that are entirely above the diagonal
        pl.when((ki * block_k) <= (qi * block_q + block_q - 1))(_compute)
    else:
        _compute()

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False
                    ) -> jnp.ndarray:
    """q/k/v (B, H, S, Dh) -> (B, H, S, Dh). S % block == 0 (callers pad)."""
    b, h, s, dh = q.shape
    assert k.shape == v.shape == (b, h, s, dh), (q.shape, k.shape)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    bh = b * h
    qr = q.reshape(bh, s, dh)
    kr = k.reshape(bh, s, dh)
    vr = v.reshape(bh, s, dh)
    kernel = functools.partial(_flash_kernel, block_q=block_q,
                               block_k=block_k, causal=causal,
                               scale=dh ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=(bh, s // block_q, s // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        scratch_shapes=[
            # (block_q, 1) running max / denom, (block_q, dh) accumulator
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s, dh)
