"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth used by tests (assert_allclose /
exact equality for integer outputs) and by the CPU fallback path in ops.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_POW2 = (2 ** jnp.arange(32, dtype=jnp.uint32)).astype(jnp.uint32)
_BIG_HAMMING = jnp.int32(1 << 30)


def hamming_scores(query_codes: jnp.ndarray,
                   item_codes: jnp.ndarray) -> jnp.ndarray:
    """All-pairs Hamming distances.

    query_codes (q, W) uint32, item_codes (n, W) uint32 -> (q, n) int32.
    """
    x = jnp.bitwise_xor(query_codes[:, None, :], item_codes[None, :, :])
    return jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.int32)


def srp_hash(x: jnp.ndarray, proj: jnp.ndarray) -> jnp.ndarray:
    """SRP sign codes, bit packed. x (n, d), proj (d, B) -> (n, B//32) uint32.

    Bit j of word w is set iff <x, proj[:, 32*w + j]> >= 0.
    """
    signs = (x @ proj) >= 0.0
    n, b = signs.shape
    grouped = signs.reshape(n, b // 32, 32).astype(jnp.uint32)
    return jnp.sum(grouped * _POW2[None, None, :], axis=-1, dtype=jnp.uint32)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True) -> jnp.ndarray:
    """O(S^2)-memory oracle for the flash attention kernel."""
    dh = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh ** -0.5
    if causal:
        sq, skv = q.shape[2], k.shape[2]
        q_pos = jnp.arange(sq) + (skv - sq)
        mask = q_pos[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def fused_scan(ucodes: jnp.ndarray, item_codes: jnp.ndarray,
               item_mask: jnp.ndarray, qitems: jnp.ndarray,
               qscale: jnp.ndarray, users: jnp.ndarray,
               n_cand: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused quantized sketch-scan oracle (DESIGN.md SS13).

    Hamming-filters one item tile against a chunk of user lanes, selects
    each lane's ``n_cand`` closest rows, and scores them with dequantized
    int8 inner products:

    ucodes (C, W) uint32, item_codes (T, W) uint32, item_mask (T,) bool,
    qitems (T, d) int8, qscale (T,) f32, users (C, d) f32
    -> (cand (C, n_cand) int32 tile-local rows, qips (C, n_cand) f32).

    Candidate order is ``jax.lax.top_k``'s: ascending Hamming distance,
    ties broken by lower row. Masked rows rank behind every live row
    (distance forced to +BIG) but still yield deterministic candidates, so
    all-masked tiles are well-defined. ``qips[c, j]`` is
    ``<float(qitems[cand[c, j]]), users[c]> * qscale[cand[c, j]]`` -- the
    scale multiplies *after* the integer-valued dot, which is what the
    error ball of ``core/sa_alsh.py::_tile_beat_int8`` assumes.
    """
    dist = hamming_scores(ucodes, item_codes)             # (C, T)
    dist = jnp.where(item_mask[None, :], dist, _BIG_HAMMING)
    _, cand = jax.lax.top_k(-dist, n_cand)                # (C, n_cand)
    qvecs = jnp.take(qitems, cand, axis=0).astype(jnp.float32)
    qips = jnp.einsum("cnd,cd->cn", qvecs, users)
    qips = qips * jnp.take(qscale, cand, axis=0)
    return cand.astype(jnp.int32), qips


def ip_topk(queries: jnp.ndarray, items: jnp.ndarray,
            k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact top-k inner products. queries (q, d), items (n, d) -> (q,k)x2.

    Returns (values f32 descending, indices int32). Ties broken by lower index
    (jax.lax.top_k convention).
    """
    scores = queries @ items.T
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)
