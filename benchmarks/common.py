"""Shared benchmark utilities: datasets, oracles, method matrix, timing."""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import exact, metrics, sah
from repro.data import synthetic

TIE_EPS = 1e-5          # queries come from the item set (see core/exact.py)


@dataclasses.dataclass
class Workload:
    name: str
    items: jnp.ndarray
    users: jnp.ndarray
    users_unit: jnp.ndarray
    queries: jnp.ndarray
    truth: dict          # k -> (nq, m) bool


def make_workload(name: str, n: int, m: int, d: int = 64, nq: int = 16,
                  ks=(1, 5, 10, 20, 30, 40, 50), kind: str = "nmf",
                  seed: int = 0) -> Workload:
    key = jax.random.PRNGKey(seed)
    ki, kq = jax.random.split(key)
    items, users = synthetic.recommendation_data(ki, n, m, d, kind=kind)
    queries = synthetic.queries_from_items(kq, items, nq)
    uu = users / jnp.linalg.norm(users, axis=-1, keepdims=True)
    truth = {k: exact.rkmips_batch_chunked(items, uu, queries, k,
                                           tie_eps=TIE_EPS) for k in ks}
    jax.block_until_ready(truth[ks[-1]])
    return Workload(name, items, users, uu, queries, truth)


# Method matrix: the paper's Fig.1 + Fig.2 ablation grid.
METHODS = {
    "SAH":        dict(transform="sat", blocking="cone", scan="sketch"),
    "SA-Simpfer": dict(transform="sat", blocking="norm", scan="sketch"),
    "H2-Cone":    dict(transform="qnf", blocking="cone", scan="sketch"),
    "H2-Simpfer": dict(transform="qnf", blocking="norm", scan="sketch"),
    "Simpfer":    dict(transform="sat", blocking="norm", scan="exact"),
}


def build_method(wl: Workload, method: str, k_max: int = 50,
                 n_bits: int = 128, seed: int = 1):
    cfg = METHODS[method]
    key = jax.random.PRNGKey(seed)
    t0 = time.perf_counter()
    idx = sah.build(wl.items, wl.users, key, k_max=k_max,
                    n_bits=n_bits, transform=cfg["transform"],
                    blocking=cfg["blocking"])
    jax.block_until_ready(idx.users)
    return idx, time.perf_counter() - t0


def run_method(wl: Workload, idx, method: str, k: int, n_cand: int = 64):
    """-> (query_time_s_per_query, f1)."""
    cfg = METHODS[method]
    m = wl.users.shape[0]
    # warm (compile)
    pred, _ = sah.rkmips_batch(idx, wl.queries, k, n_cand=n_cand,
                               scan=cfg["scan"], tie_eps=TIE_EPS)
    jax.block_until_ready(pred)
    t0 = time.perf_counter()
    pred, stats = sah.rkmips_batch(idx, wl.queries, k, n_cand=n_cand,
                                   scan=cfg["scan"], tie_eps=TIE_EPS)
    jax.block_until_ready(pred)
    dt = (time.perf_counter() - t0) / wl.queries.shape[0]
    po = sah.predictions_to_original(idx, pred, m)
    f1 = float(jnp.mean(metrics.f1_score(po, wl.truth[k])))
    return dt, f1, stats


def fmt_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
