"""Pallas TPU kernel: fused Hamming filter + quantized IP for decide_count.

This is the int8 hot path of the RkMIPS execute loop (DESIGN.md SS13). For a
chunk of user lanes and one norm-ordered item tile it fuses three stages that
the f32 path runs as separate lax ops:

  1. popcount(xor(codes))         -- the SA-ALSH sketch filter,
  2. top-``n_cand`` selection     -- survivor compaction per lane,
  3. int8 gather + dequantized IP -- the quantized screening scores.

The caller (core/sa_alsh.py::_tile_beat_int8) classifies the returned scores
against its error ball and re-ranks only the ambiguous band in exact f32, so
nothing here needs to be bitwise anything -- correctness of the final counts
depends only on ``|qips - <qitems[cand], u> * qscale[cand]|`` staying inside
the float error the ball's 1% slack absorbs (see _QERR_SLACK).

Selection uses iterated argmin rather than a sort: argmin takes the lowest
index on ties, which is exactly ``jax.lax.top_k``'s tie-break on negated
distances, so the lax mirror below is candidate-for-candidate identical to
the ref.py oracle. Selected lanes are masked to INT32_MAX; unselected
entries are at most _BIG_HAMMING (1 << 30) < INT32_MAX, so a row can never
be picked twice while any unpicked row remains.

Tiling: grid (C // block_q,). Each program instance owns ``block_q`` user
lanes and the whole (T, W) code tile / (T, d) int8 tile -- T is the core
library's partition tile (<= 4096), so at T=4096, d=128, W=8 the resident
VMEM is 4096*8*4 + 4096*128 + 4096*4 + block_q*(W*4 + d*4) ~ 0.7 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref

# Python ints, not jnp scalars: the Pallas kernel body may not capture
# traced constants, and weak-typed literals fold into int32 ops anyway.
_BIG_HAMMING = 1 << 30
_INT_MAX = 2**31 - 1


def fused_scan_lax(ucodes: jnp.ndarray, item_codes: jnp.ndarray,
                   item_mask: jnp.ndarray, qitems: jnp.ndarray,
                   qscale: jnp.ndarray, users: jnp.ndarray,
                   *, n_cand: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """lax mirror of the kernel; bitwise equal to ref.fused_scan.

    Same signature/result as ref.fused_scan but selects by iterated argmin
    instead of ``lax.top_k`` -- on CPU the O(T log T) sort inside top_k
    dominates the whole scan (BENCH kernel/fused_scan cells), while n_cand
    argmin sweeps stay O(n_cand * T) with trivial constants. Scores the
    selected rows with the identical gather + einsum the oracle uses, so the
    qips halves agree bitwise too. Not jitted: called inside already-jitted
    decide_count traces.
    """
    dist = _ref.hamming_scores(ucodes, item_codes)        # (C, T)
    dist = jnp.where(item_mask[None, :], dist, _BIG_HAMMING)
    c, t = dist.shape
    cand0 = jnp.zeros((c, n_cand), dtype=jnp.int32)

    def pick(i, state):
        d_, cand = state
        arg = jnp.argmin(d_, axis=-1)                     # ties -> lowest row
        cand = cand.at[:, i].set(arg.astype(jnp.int32))
        onehot = jax.nn.one_hot(arg, t, dtype=jnp.bool_)
        return jnp.where(onehot, _INT_MAX, d_), cand

    _, cand = jax.lax.fori_loop(0, n_cand, pick, (dist, cand0))
    qvecs = jnp.take(qitems, cand, axis=0).astype(jnp.float32)
    qips = jnp.einsum("cnd,cd->cn", qvecs, users)
    qips = qips * jnp.take(qscale, cand, axis=0)
    return cand, qips


def _fused_scan_kernel(uc_ref, codes_ref, mask_ref, qitems_ref, qscale_ref,
                       users_ref, cand_ref, qips_ref, *, n_cand):
    uc = uc_ref[...]                     # (bq, W) uint32
    codes = codes_ref[...]               # (T, W) uint32
    mask = mask_ref[...]                 # (1, T) int32
    qf = qitems_ref[...].astype(jnp.float32)   # (T, d)
    qs = qscale_ref[...]                 # (1, T) f32
    u = users_ref[...]                   # (bq, d) f32
    bq, t = uc.shape[0], codes.shape[0]

    x = jnp.bitwise_xor(uc[:, None, :], codes[None, :, :])
    dist = jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.int32)
    dist = jnp.where(mask > 0, dist, _BIG_HAMMING)        # (bq, T)

    cols = jax.lax.broadcasted_iota(jnp.int32, (bq, t), 1)

    def pick(i, d_):
        arg = jnp.argmin(d_, axis=-1).astype(jnp.int32)   # (bq,)
        onehot = cols == arg[:, None]                     # (bq, T)
        # dynamic row gather as a one-hot matmul: MXU-friendly, no
        # per-lane scatter/gather addressing inside the kernel
        row = jnp.dot(onehot.astype(jnp.float32), qf,
                      preferred_element_type=jnp.float32)  # (bq, d)
        scale = jnp.sum(jnp.where(onehot, qs, 0.0), axis=-1)
        ip = jnp.sum(row * u, axis=-1) * scale
        cand_ref[:, i] = arg
        qips_ref[:, i] = ip
        return jnp.where(onehot, _INT_MAX, d_)

    jax.lax.fori_loop(0, n_cand, pick, dist)


@functools.partial(jax.jit,
                   static_argnames=("n_cand", "block_q", "interpret"))
def fused_scan_tiles(ucodes: jnp.ndarray, item_codes: jnp.ndarray,
                     item_mask: jnp.ndarray, qitems: jnp.ndarray,
                     qscale: jnp.ndarray, users: jnp.ndarray,
                     *, n_cand: int, block_q: int = 8,
                     interpret: bool = False
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """ucodes (C, W) u32, item_codes (T, W) u32, item_mask (T,) bool,
    qitems (T, d) int8, qscale (T,) f32, users (C, d) f32
    -> (cand (C, n_cand) int32, qips (C, n_cand) f32).

    C must be a multiple of block_q (ops.py falls back to block_q=1).
    cand matches ref.fused_scan exactly; qips matches to float tolerance
    (the one-hot matmul gather reassociates the dot product).
    """
    c, w = ucodes.shape
    t, w2 = item_codes.shape
    d = qitems.shape[1]
    assert w == w2, (w, w2)
    assert c % block_q == 0, (c, block_q)
    mask2 = item_mask.astype(jnp.int32).reshape(1, t)
    qscale2 = qscale.reshape(1, t)
    grid = (c // block_q,)
    return pl.pallas_call(
        functools.partial(_fused_scan_kernel, n_cand=n_cand),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, w), lambda i: (i, 0)),
            pl.BlockSpec((t, w), lambda i: (0, 0)),
            pl.BlockSpec((1, t), lambda i: (0, 0)),
            pl.BlockSpec((t, d), lambda i: (0, 0)),
            pl.BlockSpec((1, t), lambda i: (0, 0)),
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, n_cand), lambda i: (i, 0)),
            pl.BlockSpec((block_q, n_cand), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, n_cand), jnp.int32),
            jax.ShapeDtypeStruct((c, n_cand), jnp.float32),
        ],
        interpret=interpret,
    )(ucodes, item_codes, mask2, qitems, qscale2, users)
