"""Batched plan/execute RkMIPS (core/sah.py, DESIGN.md SS9).

Hypothesis-free mirrors of the flat-queue equivalence properties (the
drawn-size versions live in tests/test_core_properties.py), plus the
compile-count regressions the tentpole is about: one trace per batch shape,
never one per query. Covers nq=1, an all-pruned batch (empty work queue),
chunk sizes from 1 to larger-than-queue, both scans, and the per-lane eps
generalization of ``sa_alsh.decide_count``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sa_alsh, sah
from repro.data import synthetic
from repro.engine import RkMIPSEngine, get_config

_LOGICAL = ("blocks_alive", "users_alive", "n_no_lb", "n_yes_norm", "n_scan")


@pytest.fixture(scope="module")
def built():
    key = jax.random.PRNGKey(17)
    ki, kq, kb = jax.random.split(key, 3)
    items, users = synthetic.recommendation_data(ki, 384, 512, 16)
    # queries from the item set exercise the tie path (ip == tau lanes)
    queries = synthetic.queries_from_items(kq, items, 5)
    idx = sah.build(items, users, kb, k_max=8, n_top=8, tile=64,
                    leaf_size=8, n_bits=32)
    return idx, queries


def _stack_oracle(idx, queries, k, **kw):
    per = [sah.rkmips(idx, q, k, **kw) for q in queries]
    pred = jnp.stack([p for p, _ in per])
    stats = jax.tree.map(lambda *xs: jnp.stack(xs), *[s for _, s in per])
    return pred, stats


@pytest.mark.parametrize("scan", ["sketch", "exact"])
@pytest.mark.parametrize("chunk", [1, 7, 64, 10_000])
def test_batched_bitwise_equals_per_query_oracle(built, scan, chunk):
    """Flat-queue predictions and the plan-time counters are bitwise the
    per-query reference driver's, for any chunking of the mixed queue."""
    idx, queries = built
    for k, tie_eps in ((1, 0.0), (3, 1e-5), (8, 0.0)):
        kw = dict(scan=scan, chunk=chunk, tie_eps=tie_eps, n_cand=16)
        bp, bs = sah.rkmips_batch(idx, queries, k, **kw)
        pp, ps = _stack_oracle(idx, queries, k, **kw)
        np.testing.assert_array_equal(np.asarray(bp), np.asarray(pp))
        for f in _LOGICAL:
            np.testing.assert_array_equal(
                np.asarray(getattr(bs, f)), np.asarray(getattr(ps, f)),
                err_msg=f"{f} k={k}")


def test_batched_matches_mapped_driver(built):
    """The legacy lax.map driver and the flat queue agree bitwise."""
    idx, queries = built
    bp, bs = sah.rkmips_batch(idx, queries, 3, n_cand=16)
    mp, ms = sah.rkmips_batch_mapped(idx, queries, 3, n_cand=16)
    np.testing.assert_array_equal(np.asarray(bp), np.asarray(mp))
    for f in _LOGICAL:
        np.testing.assert_array_equal(np.asarray(getattr(bs, f)),
                                      np.asarray(getattr(ms, f)), f)


def test_nq1_reproduces_full_stats(built):
    """A batch of one is the per-query driver, ALL counters included:
    single-query chunking is identical, so even the packing diagnostics
    (tiles_scanned, chunks) match bitwise."""
    idx, queries = built
    bp, bs = sah.rkmips_batch(idx, queries[:1], 3, n_cand=16)
    pp, ps = sah.rkmips(idx, queries[0], 3, n_cand=16)
    np.testing.assert_array_equal(np.asarray(bp[0]), np.asarray(pp))
    for f in bs._fields:
        assert int(np.asarray(getattr(bs, f))[0]) == int(getattr(ps, f)), f


def test_all_pruned_batch_empty_queue(built):
    """A batch whose every lane is decided at plan time never enters the
    execute loop: n_scan/tiles/chunks all zero, predictions still equal the
    oracle. (Huge-norm queries: tau >= ||p_k|| for every user => all-yes.)"""
    idx, queries = built
    d = queries.shape[1]
    q_huge = jnp.zeros((3, d)).at[:, 0].set(1e4)
    plan = sah.rkmips_plan(idx, q_huge, 3)
    assert int(plan.n_work) == 0
    bp, bs = sah.rkmips_batch(idx, q_huge, 3, n_cand=16)
    pp, _ = _stack_oracle(idx, q_huge, 3, n_cand=16)
    np.testing.assert_array_equal(np.asarray(bp), np.asarray(pp))
    assert not np.asarray(bs.n_scan).any()
    assert not np.asarray(bs.tiles_scanned).any()
    assert not np.asarray(bs.chunks).any()


def test_plan_queue_is_query_major_leaf_ordered(built):
    """The work queue compaction is stable: undecided lanes first, in
    query-major order with cone-leaf order preserved within each query."""
    idx, queries = built
    plan = sah.rkmips_plan(idx, queries, 3)
    n_work = int(plan.n_work)
    assert n_work == int(np.asarray(plan.n_scan).sum()) > 0
    work = np.asarray(plan.queue[:n_work])
    assert (np.diff(work) > 0).all()        # strictly increasing flat ids
    tail = np.asarray(plan.queue[n_work:])
    # the tail is exactly the decided lanes (queue is a permutation)
    assert len(np.union1d(work, tail)) == plan.queue.shape[0]


def test_full_queue_tail_chunk_is_not_dropped():
    """Regression: when (nearly) every lane is undecided and the queue
    length is not a chunk multiple, the final dynamic_slice clamps its
    start — the active mask must follow the clamp, or the tail lanes are
    silently never scanned (left at pred0=False). Constructed so ALL lanes
    are undecided and the exact answer is all-True: P' lives in the
    negative orthant (lower bounds < 0 < tau), the scanned items have norm
    0.05 < tau, and ||q|| stays below ||p_k|| so nothing decides early."""
    key = jax.random.PRNGKey(41)
    ki, ku, kb = jax.random.split(key, 3)
    d = 8
    top = -(jnp.abs(jax.random.normal(ki, (4, d))) + 0.2)
    top = top / jnp.linalg.norm(top, axis=-1, keepdims=True)       # norm 1
    rest = jnp.abs(jax.random.normal(jax.random.fold_in(ki, 1), (4, d)))
    rest = 0.05 * rest / jnp.linalg.norm(rest, axis=-1, keepdims=True)
    items = jnp.concatenate([top, rest])
    users = jnp.abs(jax.random.normal(ku, (16, d)))
    users = users.at[:, 0].add(2.0)                # tau = 0.5*u0 > 0.05
    q = jnp.zeros((d,)).at[0].set(0.5)
    idx = sah.build(items, users, kb, k_max=4, n_top=4, tile=4,
                    leaf_size=8, n_bits=32)
    uu = users / jnp.linalg.norm(users, axis=-1, keepdims=True)
    assert float(jnp.min(uu @ q)) > 0.05           # every IP beats the rest
    plan = sah.rkmips_plan(idx, q[None], 4)
    assert int(plan.n_work) == idx.n_users         # ALL 16 lanes undecided
    for chunk in (3, 5, 7):                        # 16 % chunk != 0: clamps
        bp, _ = sah.rkmips_batch(idx, q[None], 4, scan="exact", chunk=chunk)
        pp, _ = sah.rkmips(idx, q, 4, scan="exact", chunk=chunk)
        po = sah.predictions_to_original(idx, bp[0], 16)
        assert bool(np.asarray(po).all()), f"chunk={chunk}"
        np.testing.assert_array_equal(np.asarray(bp[0]), np.asarray(pp))


def test_decide_count_per_lane_eps(built):
    """Mixed-eps lanes in one chunk decide exactly as the same lanes would
    with their own scalar eps — the generalization the mixed-query queue
    rides on."""
    idx, _ = built
    alsh = idx.alsh
    key = jax.random.PRNGKey(3)
    C = 16
    rows = jax.random.randint(key, (C,), 0, idx.n_users)
    users = jnp.take(idx.users, rows, axis=0)
    taus = jnp.take(idx.users @ jnp.ones(idx.users.shape[1]) * 0.2, rows)
    counts = jnp.zeros((C,), jnp.int32)
    active = jnp.ones((C,), bool)
    eps_lane = jnp.where(jnp.arange(C) % 2 == 0, 0.0, 0.05)
    mixed, _ = sa_alsh.decide_count(alsh, users, taus, counts, active, 3,
                                    n_cand=16, eps=eps_lane)
    for eps in (0.0, 0.05):
        sel = np.asarray(eps_lane) == eps
        ref, _ = sa_alsh.decide_count(alsh, users[sel], taus[sel],
                                      counts[sel], active[sel], 3,
                                      n_cand=16, eps=eps)
        np.testing.assert_array_equal(np.asarray(mixed)[sel],
                                      np.asarray(ref))


# ---------------------------------------------------------------------------
# Compile-count regressions: batch size is a throughput knob, not a trace
# knob. The sharded mirror (shard_map body traced once per dispatch) lives
# in the 8-device subprocess script of tests/test_engine.py.
# ---------------------------------------------------------------------------


@pytest.fixture()
def engine():
    key = jax.random.PRNGKey(23)
    ki, kb = jax.random.split(key)
    items, users = synthetic.recommendation_data(ki, 256, 512, 16)
    cfg = get_config("sah").replace(tile=64, n_bits=32, k_max=8, n_top=8)
    return RkMIPSEngine(cfg).build(items, users, kb), items


def test_one_trace_per_batch_shape(engine):
    eng, items = engine
    queries = items[:4]
    eng.query_batch(queries, 3)
    eng.query_batch(queries, 3)
    eng.query_batch(items[4:8], 3)            # same shape, new values
    assert eng.rkmips_compile_count == 1
    eng.query_batch(items[:7], 3)             # new batch shape
    assert eng.rkmips_compile_count == 2
    eng.query(items[0], 3)                    # the (1, d) executable
    eng.query(items[1], 3)
    assert eng.rkmips_compile_count == 3
    eng.query_batch(queries, 4)               # new k
    assert eng.rkmips_compile_count == 4


def test_traces_do_not_scale_with_batch_size(engine, monkeypatch):
    """The batched body is invoked exactly once per trace, however many
    queries the batch holds — no Python-level loop over queries anywhere
    in the dispatch path."""
    eng, items = engine
    calls = {"n": 0}
    orig = sah.rkmips_batch_impl
    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)
    monkeypatch.setattr(sah, "rkmips_batch_impl", counting)
    # fresh jit cache: a fresh engine (the dispatch is per-instance)
    eng2 = RkMIPSEngine(eng.config).build(items, items[:32],
                                          jax.random.PRNGKey(0))
    eng2.query_batch(items[:9], 3)
    assert calls["n"] == 1, calls["n"]
    eng2.query_batch(items[:9], 3)            # cached: no retrace
    assert calls["n"] == 1, calls["n"]


def test_funnel_aggregates_stats(engine):
    eng, items = engine
    res = eng.query_batch(items[:4], 3)
    f = res.funnel
    assert f.queries == 4
    assert f.blocks_total == 4 * eng.index.n_blocks
    assert f.users_total == 4 * eng.n_users
    assert f.blocks_alive == int(np.asarray(res.stats.blocks_alive).sum())
    assert f.scan_lanes == int(np.asarray(res.stats.n_scan).sum())
    assert 0 < f.blocks_alive <= f.blocks_total
    assert f.users_alive <= f.users_total
    line = f.format()
    assert "queries" in line and "->" in line and str(f.scan_lanes) in line
    # the single-query path carries a funnel too
    assert eng.query(items[0], 3).funnel.queries == 1
