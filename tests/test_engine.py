"""repro.engine: registry parity, facade behaviour, sharded equivalence.

The engine is the only public (R)kMIPS surface; these tests pin its three
contracts: (1) every registry preset is *exactly* the raw core path with the
equivalent kwargs — bit for bit; (2) predictions come back in original
user-id space and match the exact oracle; (3) a mesh policy changes the
execution layout, never the answer (subprocess on an 8-device host mesh).
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as engine_mod
from repro.core import exact, metrics, sah
from repro.data import synthetic
from repro.engine import EngineConfig, RkMIPSEngine, get_config


@pytest.fixture(scope="module")
def workload():
    key = jax.random.PRNGKey(5)
    ki, kq = jax.random.split(key)
    items, users = synthetic.recommendation_data(ki, 1024, 2048, 32)
    queries = synthetic.queries_from_items(kq, items, 4)
    return items, users, queries


def test_config_is_frozen_and_hashable():
    cfg = get_config("sah")
    with pytest.raises(Exception):
        cfg.scan = "exact"
    assert cfg == EngineConfig()
    assert len({get_config(m) for m in engine_mod.method_names()}) == 6
    assert cfg.replace(scan="exact") == get_config("exact")


def test_config_validation():
    with pytest.raises(ValueError):
        EngineConfig(transform="nope")
    with pytest.raises(ValueError):
        EngineConfig(blocking="tree")
    with pytest.raises(ValueError):
        EngineConfig(scan="hash")
    with pytest.raises(ValueError):
        EngineConfig(b=1.5)
    with pytest.raises(ValueError):
        EngineConfig(n_bits=100)
    with pytest.raises(ValueError):
        EngineConfig(n_top=10, k_max=50)
    with pytest.raises(KeyError):
        get_config("unknown-method")


def test_registry_matrix():
    """The registry encodes exactly the DESIGN.md SS3 baseline matrix."""
    rows = {m: (c.blocking, c.transform, c.scan)
            for m, c in ((m, get_config(m))
                         for m in engine_mod.PAPER_BASELINES)}
    assert rows == {
        "sah": ("cone", "sat", "sketch"),
        "sa-simpfer": ("norm", "sat", "sketch"),
        "h2-cone": ("cone", "qnf", "sketch"),
        "h2-simpfer": ("norm", "qnf", "sketch"),
        "simpfer": ("norm", "sat", "exact"),
    }
    assert engine_mod.display_name("h2-cone") == "H2-Cone"
    # display names round-trip through the case-insensitive lookup
    for m in engine_mod.method_names():
        assert get_config(engine_mod.display_name(m)) == get_config(m)


@pytest.mark.parametrize("method", ["sah", "sa-simpfer", "h2-cone",
                                    "h2-simpfer", "simpfer", "exact"])
def test_registry_parity_with_raw_core(workload, method):
    """Engine preset == sah.build + sah.rkmips_batch with the equivalent raw
    kwargs, bit for bit (same key, same knobs, same user-space mapping)."""
    items, users, queries = workload
    key = jax.random.PRNGKey(1)
    k = 10
    cfg = get_config(method).replace(tile=256, n_bits=64)

    eng = RkMIPSEngine(cfg).build(items, users, key)
    res = eng.query_batch(queries, k)

    idx = sah.build(items, users, key, **cfg.build_kwargs())
    pred, _ = sah.rkmips_batch(idx, queries, k, **cfg.query_kwargs())
    po = sah.predictions_to_original(idx, pred, users.shape[0])
    np.testing.assert_array_equal(np.asarray(res.predictions),
                                  np.asarray(po))


def test_engine_f1_vs_exact_smoke(workload):
    """Engine-level F1 against its own oracle on the synthetic workload."""
    items, users, queries = workload
    eng = RkMIPSEngine("sah").build(items, users, jax.random.PRNGKey(2))
    res = eng.query_batch(queries, 10)
    truth = eng.oracle(queries, 10)
    assert res.predictions.shape == truth.shape == (4, users.shape[0])
    f1 = float(jnp.mean(metrics.f1_score(res.predictions, truth)))
    assert f1 > 0.9, f1
    assert res.seconds > 0 and res.k == 10
    # the "exact" preset must reach F1 == 1 exactly (linear scan)
    eng_x = RkMIPSEngine("exact").build(items, users, jax.random.PRNGKey(2))
    rx = eng_x.query_batch(queries, 10)
    np.testing.assert_array_equal(np.asarray(rx.predictions),
                                  np.asarray(eng_x.oracle(queries, 10)))


def test_query_single_matches_batch(workload):
    items, users, queries = workload
    eng = RkMIPSEngine("sah").build(items, users, jax.random.PRNGKey(3))
    batch = eng.query_batch(queries, 5)
    single = eng.query(queries[0], 5)
    assert single.predictions.shape == (users.shape[0],)
    np.testing.assert_array_equal(np.asarray(single.predictions),
                                  np.asarray(batch.predictions[0]))


def test_k_and_lifecycle_guards(workload):
    items, users, queries = workload
    eng = RkMIPSEngine(get_config("sah").replace(k_max=20))
    with pytest.raises(RuntimeError):
        eng.query(queries[0], 5)        # not built
    with pytest.raises(RuntimeError):
        eng.oracle(queries, 5)
    eng.build(items, users, jax.random.PRNGKey(4))
    with pytest.raises(ValueError):
        eng.query(queries[0], 21)       # k > k_max
    with pytest.raises(ValueError):
        eng.query(queries[0], 0)
    # kMIPS-only engine: forward queries fine, reverse queries guarded
    eng_k = RkMIPSEngine("sah").build(items, None, jax.random.PRNGKey(4))
    assert eng_k.kmips(queries[0], 5).ids.shape == (5,)
    with pytest.raises(RuntimeError):
        eng_k.query(queries[0], 5)


def test_error_messages(workload):
    """Engine error paths raise actionable, message-stable exceptions:
    unknown preset, k outside [1, k_max], querying before build."""
    items, users, queries = workload
    with pytest.raises(KeyError,
                       match=r"unknown engine method 'no-such-method'; "
                             r"known: .*sah"):
        get_config("no-such-method")
    with pytest.raises(TypeError, match=r"config must be an EngineConfig "
                                        r"or a registry name"):
        RkMIPSEngine(42)

    eng = RkMIPSEngine(get_config("sah").replace(k_max=20))
    for call in (lambda: eng.query(queries[0], 5),
                 lambda: eng.query_batch(queries, 5)):
        with pytest.raises(RuntimeError,
                           match=r"engine not built for RkMIPS: call "
                                 r"build\(items, users, key\) first"):
            call()
    with pytest.raises(RuntimeError, match=r"engine not built for RkMIPS"):
        eng.oracle(queries, 5)
    for call in (lambda: eng.kmips(queries[0], 5), lambda: eng.server()):
        with pytest.raises(RuntimeError,
                           match=r"engine not built: call "
                                 r"build\(items, users, key\) first"):
            call()

    eng.build(items[:256], users[:256], jax.random.PRNGKey(10))
    with pytest.raises(ValueError,
                       match=r"k=21 outside \[1, k_max=20\] supported by "
                             r"this index; rebuild with a larger k_max"):
        eng.query(queries[0], 21)
    with pytest.raises(ValueError, match=r"k=0 outside \[1, k_max=20\]"):
        eng.query_batch(queries, 0)


def test_rebuild_resets_state(workload):
    """A second build() must drop every artifact of the first — serving a
    stale kMIPS index or user-side arrays would be silently wrong."""
    items, users, queries = workload
    eng = RkMIPSEngine("sah").build(items, users, jax.random.PRNGKey(8))
    eng.kmips(queries[0], 5)                  # materialize the lazy index
    first_kmips = eng.kmips_index
    eng.build(items[:512], users[:512], jax.random.PRNGKey(9))
    assert eng.n_users == 512
    assert eng.kmips_index is not first_kmips
    assert eng.kmips_index.item_mask.shape[0] >= 512
    assert eng.query(queries[0], 5).predictions.shape == (512,)
    # kMIPS-only rebuild drops the user side entirely
    eng.build(items, None, jax.random.PRNGKey(8))
    with pytest.raises(RuntimeError):
        eng.query(queries[0], 5)


def test_kmips_recall(workload):
    """Forward kMIPS through the facade: recall against the exact top-k."""
    items, users, queries = workload
    eng = RkMIPSEngine("sah").build(items, None, jax.random.PRNGKey(6))
    k = 10
    res = eng.kmips(queries, k, n_cand=128)
    _, ti = exact.kmips(items, queries, k)
    rec = float(jnp.mean(metrics.recall_at_k(res.ids, ti)))
    assert rec > 0.8, rec
    assert res.values.shape == (4, k)
    # values are the actual inner products of the returned ids, descending
    ips = jnp.take_along_axis(queries @ items.T, res.ids, axis=-1)
    np.testing.assert_allclose(np.asarray(res.values), np.asarray(ips),
                               rtol=1e-5)
    assert bool(jnp.all(res.values[:, :-1] >= res.values[:, 1:]))


def test_serving_codes_row_order():
    """Artifact serving_codes returns sketches in *input* row order: row
    i's code must equal the code the artifact's kMIPS index computed for
    the item that landed at original row i (the launch/serve.py contract);
    the legacy ``engine.serving_codes`` shim forwards to the same surface
    and warns."""
    key = jax.random.PRNGKey(7)
    items = jax.random.normal(key, (96, 16))
    cfg = get_config("sah").replace(n_bits=64)
    art = engine_mod.IndexArtifact.build(items, None, key, config=cfg)
    codes, proj_q = art.serving_codes()
    assert codes.shape == (96, 2) and codes.dtype == jnp.uint32
    assert proj_q.shape == (16, 64)
    idx = art.kmips_index                   # built eagerly for users=None
    ids = np.asarray(idx.item_ids)
    mask = np.asarray(idx.item_mask)
    np.testing.assert_array_equal(np.asarray(codes)[ids[mask]],
                                  np.asarray(idx.codes)[mask])
    np.testing.assert_array_equal(np.asarray(proj_q),
                                  np.asarray(idx.proj[:-1]))
    # the deprecated shim: same codes, same projection, plus a warning
    with pytest.warns(DeprecationWarning, match=r"serving_codes is "
                                                r"deprecated"):
        codes_shim, proj_shim = engine_mod.serving_codes(items, key,
                                                         n_bits=64)
    np.testing.assert_array_equal(np.asarray(codes_shim), np.asarray(codes))
    np.testing.assert_array_equal(np.asarray(proj_shim), np.asarray(proj_q))
    # launch/serve.py::build_candidate_index rides the artifact surface
    from repro.launch import serve as serve_mod
    codes_l, proj_l = serve_mod.build_candidate_index(items, key, n_bits=64)
    np.testing.assert_array_equal(np.asarray(codes_l), np.asarray(codes))


_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.engine import RkMIPSEngine, get_config
from repro.dist.policy import ShardingPolicy
from repro.data import synthetic
from repro.core import exact

key = jax.random.PRNGKey(0)
ki, kq, kb = jax.random.split(key, 3)
items, users = synthetic.recommendation_data(ki, 512, 1024, 32)
queries = synthetic.queries_from_items(kq, items, 3)

mesh = jax.make_mesh((2, 4), ("data", "model"))
policy = ShardingPolicy(mesh=mesh, rules={})

# RkMIPS: sharded predictions must be bitwise equal to single-device.
for method in ("sah", "simpfer"):
    cfg = get_config(method).replace(tile=128, n_bits=64)
    e0 = RkMIPSEngine(cfg).build(items, users, kb)
    e1 = RkMIPSEngine(cfg, policy=policy).build(items, users, kb)
    r0 = e0.query_batch(queries, 10)
    r1 = e1.query_batch(queries, 10)
    np.testing.assert_array_equal(np.asarray(r0.predictions),
                                  np.asarray(r1.predictions))
    # per-user counters are layout-independent (chunks/tiles are not)
    for f in ("blocks_alive", "users_alive", "n_no_lb", "n_yes_norm",
              "n_scan"):
        np.testing.assert_array_equal(np.asarray(getattr(r0.stats, f)),
                                      np.asarray(getattr(r1.stats, f)))
    s1 = e1.query(queries[0], 10)
    np.testing.assert_array_equal(np.asarray(s1.predictions),
                                  np.asarray(r1.predictions[0]))
    print(method, "rkmips sharded OK")

# The sharded path contains no Python-level loop over queries: one trace of
# the batched plan/execute body per shard_map dispatch, at any batch size
# (the jax 0.4.x per-query unroll is retired, DESIGN.md SS9).
from repro.core import sah as sah_mod
cfg = get_config("sah").replace(tile=128, n_bits=64)
e1 = RkMIPSEngine(cfg, policy=policy).build(items, users, kb)
calls = {"n": 0}
orig_impl = sah_mod.rkmips_batch_impl
def counting_impl(*a, **kw):
    calls["n"] += 1
    return orig_impl(*a, **kw)
sah_mod.rkmips_batch_impl = counting_impl
try:
    e1.query_batch(queries, 10)
finally:
    sah_mod.rkmips_batch_impl = orig_impl
assert calls["n"] == 1, f"sharded body traced {calls['n']} times for nq=3"
# engine-level compile accounting under a mesh: one per distinct batch shape
assert e1.rkmips_compile_count == 1, e1.rkmips_compile_count
e1.query_batch(queries, 10)
assert e1.rkmips_compile_count == 1, e1.rkmips_compile_count
e1.query_batch(queries[:2], 10)
assert e1.rkmips_compile_count == 2, e1.rkmips_compile_count
print("sharded single-trace OK")

# kMIPS: with full per-shard re-rank depth both layouts recover the exact
# top-k, so sharded and unsharded agree on the ids.
cfg = get_config("sah").replace(tile=128, n_bits=64)
e0 = RkMIPSEngine(cfg).build(items, None, kb)
e1 = RkMIPSEngine(cfg, policy=policy).build(items, None, kb)
_, ti = exact.kmips(items, queries, 5)
k0 = e0.kmips(queries, 5, n_cand=512)
k1 = e1.kmips(queries, 5, n_cand=512)
np.testing.assert_array_equal(np.asarray(k0.ids), np.asarray(ti))
np.testing.assert_array_equal(np.asarray(k1.ids), np.asarray(ti))
# the flat scan's single-device oracle agrees with its sharded body
from repro.dist.policy import NO_SHARDING
from repro.engine import sharding as eng_sharding
fv, fi = eng_sharding.kmips_flat(e1.kmips_index, queries, 5, NO_SHARDING,
                                 n_cand=512)
np.testing.assert_array_equal(np.asarray(fi), np.asarray(ti))
# exact-scan presets stay exact under a mesh regardless of n_cand
e1x = RkMIPSEngine(cfg.replace(scan="exact"), policy=policy).build(
    items, None, kb)
kx = e1x.kmips(queries, 5, n_cand=8)
np.testing.assert_array_equal(np.asarray(kx.ids), np.asarray(ti))
print("kmips sharded OK")

# Non-divisible counts shard via dead padding, bitwise equal to one device
# (DESIGN.md SS8): 1009 users -> 32 cone blocks padded to 36 over a
# 6-device (2, 3) mesh; 997 items -> 1024 padded rows -> 1026.
items_p, users_p = synthetic.recommendation_data(ki, 997, 1009, 32)
queries_p = synthetic.queries_from_items(kq, items_p, 2)
mesh6 = jax.sharding.Mesh(np.asarray(jax.devices()[:6]).reshape(2, 3),
                          ("data", "model"))
policy6 = ShardingPolicy(mesh=mesh6, rules={})
cfgp = get_config("sah").replace(tile=128, n_bits=64)
e0 = RkMIPSEngine(cfgp).build(items_p, users_p, kb)
e1 = RkMIPSEngine(cfgp, policy=policy6).build(items_p, users_p, kb)
assert e1.index.n_blocks % 6 == 0 and e1.index.n_blocks == 36
r0 = e0.query_batch(queries_p, 10)
r1 = e1.query_batch(queries_p, 10)
np.testing.assert_array_equal(np.asarray(r0.predictions),
                              np.asarray(r1.predictions))
for f in ("blocks_alive", "users_alive", "n_no_lb", "n_yes_norm", "n_scan"):
    np.testing.assert_array_equal(np.asarray(getattr(r0.stats, f)),
                                  np.asarray(getattr(r1.stats, f)))
k0 = e0.kmips(queries_p, 5, n_cand=1024)
k1 = e1.kmips(queries_p, 5, n_cand=1024)
_, tip = exact.kmips(items_p, queries_p, 5)
np.testing.assert_array_equal(np.asarray(k0.ids), np.asarray(tip))
np.testing.assert_array_equal(np.asarray(k1.ids), np.asarray(tip))
print("non-divisible padding OK")

# Fewer blocks than devices pads up too (96 users -> 4 blocks -> 8).
cfg3 = get_config("sah").replace(tile=128)
e0 = RkMIPSEngine(cfg3).build(items[:256], users[:96], kb)
e1 = RkMIPSEngine(cfg3, policy=policy).build(items[:256], users[:96], kb)
assert e1.index.n_blocks == 8
r0 = e0.query_batch(queries, 10)
r1 = e1.query_batch(queries, 10)
np.testing.assert_array_equal(np.asarray(r0.predictions),
                              np.asarray(r1.predictions))
print("small-block padding OK")
print("ALL ENGINE SHARDED OK")
"""


@pytest.mark.slow
def test_engine_sharded_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL ENGINE SHARDED OK" in out.stdout
    assert "sharded single-trace OK" in out.stdout
    assert "non-divisible padding OK" in out.stdout
    assert "small-block padding OK" in out.stdout
