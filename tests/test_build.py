"""Staged build pipeline tests (engine/build.py, DESIGN.md SS11).

The contract under test: ``build_sah_index`` composes the same stage
functions as ``core/sah.py::build``, so (a) the single-device staged build
is bitwise identical to the legacy monolith for every registry method, and
(b) sharding the row-parallel stages — SRP hashing over item rows, Simpfer
lower bounds over user rows — changes nothing, bit for bit, for ANY shard
count and ANY (prime, non-divisible) m/n. (a)+(b) are what make the
sharded-on-a-mesh artifact fingerprint-identical (and leaf-for-leaf
bitwise identical) to the single-device one; the real 8-device mesh is
pinned by the slow subprocess test at the bottom, the in-process tests
pin the same row-slicing through the ``shards`` simulation seam.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cone as cone_lib
from repro.core import sah as sah_lib
from repro.engine import (IndexArtifact, RkMIPSEngine, get_config,
                          method_names)
from repro.engine.build import (BuildTimings, build_sah_index,
                                validate_build_knobs)

KEY = jax.random.PRNGKey(11)
# Primes on purpose: nothing divides the shard counts below.
N_ITEMS, M_USERS, DIM = 509, 131, 16


def _corpus(n=N_ITEMS, m=M_USERS, d=DIM):
    ki, ku = jax.random.split(KEY)
    items = jax.random.normal(ki, (n, d)) * \
        jnp.linspace(0.5, 2.0, n)[:, None]
    users = jax.random.normal(ku, (m, d))
    return items, users


def _cfg(method="sah", **kw):
    base = dict(k_max=4, tile=64, n_bits=64, leaf_size=8)
    base.update(kw)
    return get_config(method).replace(**base)


def _assert_index_equal(a, b, ctx=""):
    paths = [str(p) for p, _ in jax.tree_util.tree_flatten_with_path(a)[0]]
    for name, la, lb in zip(paths, jax.tree.leaves(a), jax.tree.leaves(b)):
        la, lb = np.asarray(la), np.asarray(lb)
        assert la.dtype == lb.dtype and la.shape == lb.shape, (ctx, name)
        np.testing.assert_array_equal(la, lb, err_msg=f"{ctx} leaf {name}")


# ---------------------------------------------------------------------------
# Staged composition == legacy monolith, per registry method.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", method_names())
def test_staged_build_matches_legacy_bitwise(method):
    items, users = _corpus()
    cfg = _cfg(method)
    kb = jax.random.fold_in(KEY, 3)
    staged, timings = build_sah_index(items, users, kb, config=cfg)
    legacy = sah_lib.build(items, users, kb, **cfg.build_kwargs())
    _assert_index_equal(staged, legacy, ctx=method)
    assert isinstance(timings, BuildTimings) and not timings.sharded
    assert timings.total >= 0 and "single-device" in timings.format()


# ---------------------------------------------------------------------------
# Sharded == single-device, bitwise (simulated row slicing; the real-mesh
# shard_map is pinned by the slow subprocess test).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shards", [2, 3, 5, 8])
def test_sharded_build_bitwise_equal(shards):
    items, users = _corpus()
    cfg = _cfg()
    kb = jax.random.fold_in(KEY, 3)
    single, t0 = build_sah_index(items, users, kb, config=cfg)
    sharded, t1 = build_sah_index(items, users, kb, config=cfg,
                                  shards=shards)
    assert not t0.sharded and t1.sharded and "sharded" in t1.format()
    _assert_index_equal(sharded, single, ctx=f"shards={shards}")


@pytest.mark.parametrize("n,m", [(97, 7), (130, 64), (259, 101)])
def test_sharded_build_bitwise_equal_odd_sizes(n, m):
    # Non-shard-divisible and prime row counts ride the dead zero-row
    # padding of row_parallel; the padding must never leak into results.
    items, users = _corpus(n=n, m=m)
    cfg = _cfg(k_max=3, tile=32, leaf_size=4)
    kb = jax.random.fold_in(KEY, 5)
    single, _ = build_sah_index(items, users, kb, config=cfg)
    for shards in (3, 8):
        sharded, _ = build_sah_index(items, users, kb, config=cfg,
                                     shards=shards)
        _assert_index_equal(sharded, single, ctx=f"n={n} m={m} s={shards}")


def test_sharded_build_property():
    """Hypothesis property: arbitrary (n, m, shards) -> bitwise equality."""
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    cfg = _cfg(k_max=2, tile=16, leaf_size=4, n_bits=32)
    kb = jax.random.fold_in(KEY, 7)

    @hypothesis.settings(max_examples=15, deadline=None,
                         suppress_health_check=[
                             hypothesis.HealthCheck.too_slow])
    @hypothesis.given(n=st.integers(8, 120), m=st.integers(2, 60),
                      shards=st.integers(2, 9))
    def prop(n, m, shards):
        items, users = _corpus(n=n, m=m, d=8)
        single, _ = build_sah_index(items, users, kb, config=cfg)
        sharded, _ = build_sah_index(items, users, kb, config=cfg,
                                     shards=shards)
        _assert_index_equal(sharded, single,
                            ctx=f"n={n} m={m} s={shards}")

    prop()


# ---------------------------------------------------------------------------
# Satellite: cone.norm_blocks parity with the reference inline math.
# ---------------------------------------------------------------------------


def test_norm_blocks_parity():
    _, users = _corpus()
    uu = users / jnp.linalg.norm(users, axis=-1, keepdims=True)
    leaf = 8
    blocks, padded, mask = cone_lib.norm_blocks(uu, leaf)
    # Reference: the math sah.build used to inline for blocking="norm".
    ref_padded, ref_mask, n_leaves = cone_lib.pad_users(uu, leaf)
    xl = ref_padded.reshape(n_leaves, leaf, -1)
    center = jnp.mean(xl, axis=1)
    cnorm = jnp.linalg.norm(center, axis=-1, keepdims=True)
    cos = jnp.einsum("bld,bd->bl", xl, center) / jnp.maximum(cnorm, 1e-12)
    theta = jnp.arccos(jnp.clip(cos, -1.0, 1.0))
    np.testing.assert_array_equal(np.asarray(blocks.perm),
                                  np.arange(ref_padded.shape[0]))
    np.testing.assert_array_equal(np.asarray(padded), np.asarray(ref_padded))
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(ref_mask))
    np.testing.assert_array_equal(np.asarray(blocks.center),
                                  np.asarray(center))
    np.testing.assert_array_equal(np.asarray(blocks.omega),
                                  np.asarray(jnp.max(theta, axis=-1)))
    np.testing.assert_array_equal(np.asarray(blocks.theta),
                                  np.asarray(theta.reshape(-1)))
    assert blocks.n_blocks == n_leaves and blocks.leaf_size == leaf


def test_norm_blocks_same_contract_as_cone():
    # Both helpers must return the (blocks, padded, mask) triple sah.build
    # consumes, with perm/theta indexing the padded array.
    _, users = _corpus(m=37)
    uu = users / jnp.linalg.norm(users, axis=-1, keepdims=True)
    for helper in (cone_lib.norm_blocks,
                   lambda u, l: cone_lib.build_cone_blocks(
                       u, jax.random.fold_in(KEY, 1), l)):
        blocks, padded, mask = helper(uu, 8)
        m_pad = padded.shape[0]
        assert blocks.perm.shape == (m_pad,)
        assert blocks.theta.shape == (m_pad,)
        assert mask.shape == (m_pad,)
        assert int(np.asarray(mask).sum()) == 37
        assert blocks.center.shape[0] * blocks.leaf_size == m_pad


# ---------------------------------------------------------------------------
# Satellite: build-knob validation before tracing.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("knob", ["k_max", "leaf_size", "n_bits", "tile"])
@pytest.mark.parametrize("bad", [0, -3])
def test_build_rejects_nonpositive_knobs(knob, bad):
    items, users = _corpus(n=64, m=8)
    cfg = _cfg()
    # EngineConfig validates at construction; corrupt the frozen instance
    # to model a config that reached build() without passing __post_init__.
    object.__setattr__(cfg, knob, bad)
    with pytest.raises(ValueError,
                       match=f"build knob {knob} must be a positive int"):
        validate_build_knobs(cfg)
    with pytest.raises(ValueError,
                       match=f"build knob {knob} must be a positive int"):
        IndexArtifact.build(items, users, jax.random.fold_in(KEY, 2),
                            config=cfg)
    with pytest.raises(ValueError, match=f"build knob {knob}"):
        build_sah_index(items, users, jax.random.fold_in(KEY, 2),
                        config=cfg)


def test_build_rejects_unaligned_n_bits():
    cfg = _cfg()
    object.__setattr__(cfg, "n_bits", 48)
    with pytest.raises(ValueError, match="multiple of 32"):
        validate_build_knobs(cfg)


def test_build_rejects_small_n_top():
    cfg = _cfg()
    object.__setattr__(cfg, "n_top", 2)   # < k_max = 4
    with pytest.raises(ValueError, match="n_top .* must be >= k_max"):
        validate_build_knobs(cfg)


def test_engine_config_validates_build_sharding():
    with pytest.raises(ValueError, match="build_sharding must be one of"):
        _cfg(build_sharding="mesh")
    for mode in ("auto", "single", "sharded"):
        assert _cfg(build_sharding=mode).build_sharding == mode


# ---------------------------------------------------------------------------
# build_sharding semantics + lifecycle integration.
# ---------------------------------------------------------------------------


def test_build_sharding_single_overrides_shards():
    items, users = _corpus(n=64, m=16)
    cfg = _cfg(build_sharding="single")
    _, timings = build_sah_index(items, users, jax.random.fold_in(KEY, 2),
                                 config=cfg, shards=4)
    assert not timings.sharded


def test_build_sharding_sharded_requires_mesh():
    items, users = _corpus(n=64, m=16)
    cfg = _cfg(build_sharding="sharded")
    with pytest.raises(ValueError, match="requires a multi-device mesh"):
        build_sah_index(items, users, jax.random.fold_in(KEY, 2),
                        config=cfg)
    # ... but the shards testing seam satisfies it.
    _, timings = build_sah_index(items, users, jax.random.fold_in(KEY, 2),
                                 config=cfg, shards=2)
    assert timings.sharded


def test_fingerprint_ignores_build_sharding():
    items, users = _corpus(n=64, m=16)
    kb = jax.random.fold_in(KEY, 2)
    fps = {IndexArtifact.build(items, users, kb,
                               config=_cfg(build_sharding=m)).fingerprint
           for m in ("auto", "single")}
    assert len(fps) == 1


def test_attach_ignores_build_sharding():
    items, users = _corpus(n=64, m=16)
    kb = jax.random.fold_in(KEY, 2)
    art = IndexArtifact.build(items, users, kb,
                              config=_cfg(build_sharding="single"))
    eng = RkMIPSEngine(_cfg(build_sharding="auto")).attach(art)
    assert eng.artifact is art


def test_engine_build_exposes_timings():
    items, users = _corpus(n=64, m=16)
    eng = RkMIPSEngine(_cfg()).build(items, users,
                                     jax.random.fold_in(KEY, 2))
    tm = eng.build_timings
    assert isinstance(tm, BuildTimings)
    assert tm.total == pytest.approx(tm.norm_split + tm.item_codes
                                     + tm.user_blocking + tm.lower_bounds)
    assert "norm-split" in tm.format()
    # compact() on a mutated artifact rebuilds through the pipeline:
    art2 = eng.artifact.insert_items(items[:2]).compact()
    assert isinstance(art2.build_timings, BuildTimings)
    # lifecycle mutations inherit the base build's timings
    assert art2.insert_items(items[:1]).build_timings is art2.build_timings


# ---------------------------------------------------------------------------
# Real 8-device host mesh (subprocess; CI job distributed-build).
# ---------------------------------------------------------------------------

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile
import jax, jax.numpy as jnp, numpy as np
import repro
from repro.dist.policy import ShardingPolicy
from repro.engine import IndexArtifact, RkMIPSEngine, get_config, \
    method_names

key = jax.random.PRNGKey(11)
ki, ku = jax.random.split(key)
# primes: neither axis divides 8 devices or the 2x4 mesh
items = jax.random.normal(ki, (509, 16)) * \
    jnp.linspace(0.5, 2.0, 509)[:, None]
users = jax.random.normal(ku, (131, 16))
kb = jax.random.fold_in(key, 3)

meshes = [jax.make_mesh((8,), ("data",)),
          jax.make_mesh((2, 4), ("data", "model"))]

for method in method_names():
    cfg = get_config(method).replace(k_max=4, tile=64, n_bits=64,
                                     leaf_size=8)
    single = IndexArtifact.build(items, users, kb, config=cfg)
    assert not single.build_timings.sharded
    for mesh in meshes:
        pol = ShardingPolicy(mesh=mesh, rules={})
        art = IndexArtifact.build(items, users, kb, config=cfg, policy=pol)
        assert art.build_timings.sharded
        assert art.fingerprint == single.fingerprint, (method, mesh.shape)
        for a, b in zip(jax.tree.leaves(art.index),
                        jax.tree.leaves(single.index)):
            a, b = np.asarray(a), np.asarray(b)
            assert a.dtype == b.dtype and np.array_equal(a, b), \
                (method, mesh.shape)
    print(f"{method} mesh-build bitwise OK")
print("all registry methods fingerprint-identical OK")

# build_sharding="single" under a mesh: same artifact, no shard_map
pol = ShardingPolicy(mesh=meshes[0], rules={})
cfg = get_config("sah").replace(k_max=4, tile=64, n_bits=64, leaf_size=8)
forced = IndexArtifact.build(items, users, kb,
                             config=cfg.replace(build_sharding="single"),
                             policy=pol)
assert not forced.build_timings.sharded
base = IndexArtifact.build(items, users, kb, config=cfg)
assert forced.fingerprint == base.fingerprint
print("build_sharding=single override OK")

# save on mesh -> load + serve on a single device
sharded = IndexArtifact.build(items, users, kb, config=cfg, policy=pol)
with tempfile.TemporaryDirectory() as d:
    sharded.save(d)
    back = IndexArtifact.load(d)
    assert back.fingerprint == sharded.fingerprint
    eng_s = RkMIPSEngine.from_artifact(back)          # NO_SHARDING
    eng_0 = RkMIPSEngine.from_artifact(base)
    q = items[:4]
    r_s = eng_s.query_batch(q, 3)
    r_0 = eng_0.query_batch(q, 3)
    np.testing.assert_array_equal(np.asarray(r_s.predictions),
                                  np.asarray(r_0.predictions))
print("save-on-mesh/load-on-single roundtrip OK")
print("ALL DISTRIBUTED BUILD OK")
"""


@pytest.mark.slow
def test_distributed_build_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL DISTRIBUTED BUILD OK" in out.stdout
    assert "all registry methods fingerprint-identical OK" in out.stdout
    assert "build_sharding=single override OK" in out.stdout
    assert "save-on-mesh/load-on-single roundtrip OK" in out.stdout
