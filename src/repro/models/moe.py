"""Mixture-of-Experts FFN: top-k routing, sort-based capacity dispatch,
expert parallelism via shard_map all-to-all.

Dispatch is MegaBlocks-style rather than GShard one-hot einsums: assignments
are sorted by expert id, positions-within-expert computed with searchsorted,
and tokens over capacity are dropped. This keeps every shape static and the
peak intermediate at (E, C, D) instead of GShard's (T, E, C) dispatch mask --
the latter is infeasible at T = 65k tokens/shard.

Expert parallelism (DESIGN.md SS5): expert weights are sharded over the
'model' mesh axis. Each (data x model) shard routes its local tokens into
per-expert buffers (E, C_local, D); one all_to_all over 'model' regroups them
as (E_local, C_local * tp, D); experts run as grouped GEMMs; a second
all_to_all sends results home. With mesh=None (or tp=1) the same dispatch
runs locally -- smoke tests exercise the identical code path minus the
collectives.

Aux: switch-style load-balance loss (mean_e frac_tokens_e * mean_router_p_e).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.policy import ShardingPolicy


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25


def init_moe_params(key: jax.Array, d_model: int, cfg: MoEConfig,
                    dtype=jnp.float32) -> dict[str, Any]:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    e, f = cfg.n_experts, cfg.d_ff_expert
    scale_in = d_model ** -0.5
    scale_out = f ** -0.5
    return {
        "router": (jax.random.normal(kr, (d_model, e)) * scale_in
                   ).astype(jnp.float32),  # router kept in f32
        "w_in": (jax.random.normal(k1, (e, d_model, f)) * scale_in
                 ).astype(dtype),
        "w_gate": (jax.random.normal(k2, (e, d_model, f)) * scale_in
                   ).astype(dtype),
        "w_out": (jax.random.normal(k3, (e, f, d_model)) * scale_out
                  ).astype(dtype),
    }


def _dispatch_indices(expert_ids: jnp.ndarray, n_experts: int, capacity: int):
    """Sort-based dispatch. expert_ids (A,) -> (slot (A,), keep (A,)).

    slot[a] in [0, n_experts * capacity) is the dispatch-buffer row of
    assignment a; keep[a] is False for over-capacity (dropped) assignments.
    """
    a = expert_ids.shape[0]
    order = jnp.argsort(expert_ids)                      # stable
    sorted_e = expert_ids[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(n_experts))
    pos_sorted = jnp.arange(a) - starts[sorted_e]
    keep_sorted = pos_sorted < capacity
    slot_sorted = sorted_e * capacity + jnp.minimum(pos_sorted, capacity - 1)
    # Back to assignment order.
    inv = jnp.argsort(order)
    return slot_sorted[inv], keep_sorted[inv]


def _expert_ffn(buf: jnp.ndarray, w_in, w_gate, w_out) -> jnp.ndarray:
    """Grouped SwiGLU: buf (E, C, D) -> (E, C, D)."""
    h = jnp.einsum("ecd,edf->ecf", buf, w_in)
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, w_out)


def _moe_local(x2d: jnp.ndarray, params, cfg: MoEConfig, capacity: int,
               w_in, w_gate, w_out):
    """Route + dispatch + expert-FFN + combine for one shard's tokens.

    x2d (T, D). w_* may be the local expert shard (E_local, ...) together with
    an axis_name to all_to_all over; here they must cover all cfg.n_experts
    (the shard_map wrapper handles the EP exchange around this function).
    """
    t, d = x2d.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = x2d.astype(jnp.float32) @ params["router"]   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                # (T, k)
    gates = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                            # (T*k,)
    slot, keep = _dispatch_indices(flat_e, e, capacity)
    token_of = jnp.repeat(jnp.arange(t), k)

    buf = jnp.zeros((e * capacity, d), x2d.dtype)
    buf = buf.at[jnp.where(keep, slot, e * capacity)].set(
        x2d[token_of], mode="drop")
    buf = buf.reshape(e, capacity, d)

    out_buf = _expert_ffn(buf, w_in, w_gate, w_out)       # (E, C, D)

    rows = out_buf.reshape(e * capacity, d)[slot]         # (T*k, D)
    rows = jnp.where(keep[:, None], rows, 0.0)
    combined = jnp.sum(
        rows.reshape(t, k, d) * gates[..., None].astype(x2d.dtype), axis=1)

    # Load-balance aux loss (Switch Transformer eq. 4).
    frac_tokens = jnp.mean(
        jax.nn.one_hot(flat_e, e, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return combined, aux


def moe_ffn(x: jnp.ndarray, params, cfg: MoEConfig,
            policy: ShardingPolicy) -> tuple[jnp.ndarray, jnp.ndarray]:
    """MoE FFN over (B, S, D) activations. Returns (out (B,S,D), aux_loss ())."""
    b, s, d = x.shape
    tp = policy.model_axis_size

    if tp == 1:
        t = b * s
        capacity = max(cfg.top_k, int(
            cfg.capacity_factor * t * cfg.top_k / cfg.n_experts))
        out, aux = _moe_local(x.reshape(t, d), params, cfg, capacity,
                              params["w_in"], params["w_gate"],
                              params["w_out"])
        return out.reshape(b, s, d), aux

    mesh = policy.mesh
    dp = policy.dp_axes()
    act_spec = policy.spec("act_btd")
    b_l = b // _spec_dim_size(mesh, act_spec, 0)
    s_l = s // _spec_dim_size(mesh, act_spec, 1)
    t_local = b_l * s_l
    capacity = max(cfg.top_k, int(
        cfg.capacity_factor * t_local * cfg.top_k / cfg.n_experts))
    assert cfg.n_experts % tp == 0, (cfg.n_experts, tp)

    def shard_fn(x_l, router, w_in_l, w_gate_l, w_out_l):
        # x_l: (B_l, S_l, D) local tokens of this (dp x tp) shard.
        bl, sl, _ = x_l.shape
        tl = bl * sl
        x2d = x_l.reshape(tl, d)
        lp = {"router": router}

        # Local route + dispatch into the global-expert buffer layout.
        logits = x2d.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
        gates = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
        flat_e = top_e.reshape(-1)
        slot, keep = _dispatch_indices(flat_e, cfg.n_experts, capacity)
        token_of = jnp.repeat(jnp.arange(tl), cfg.top_k)
        buf = jnp.zeros((cfg.n_experts * capacity, d), x2d.dtype)
        buf = buf.at[jnp.where(keep, slot, cfg.n_experts * capacity)].set(
            x2d[token_of], mode="drop")
        buf = buf.reshape(cfg.n_experts, capacity, d)

        # EP exchange: (E, C, D) -> (E_local, C * tp, D).
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                 tiled=True)
        out_buf = _expert_ffn(buf, w_in_l, w_gate_l, w_out_l)
        out_buf = jax.lax.all_to_all(out_buf, "model", split_axis=1,
                                     concat_axis=0, tiled=True)

        rows = out_buf.reshape(cfg.n_experts * capacity, d)[slot]
        rows = jnp.where(keep[:, None], rows, 0.0)
        combined = jnp.sum(
            rows.reshape(tl, cfg.top_k, d) * gates[..., None].astype(x_l.dtype),
            axis=1)

        frac_tokens = jnp.mean(
            jax.nn.one_hot(flat_e, cfg.n_experts, dtype=jnp.float32), axis=0)
        frac_probs = jnp.mean(probs, axis=0)
        aux = cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
        aux = jax.lax.pmean(aux, ("model",) + dp)
        del lp
        return combined.reshape(bl, sl, d), aux

    out, aux = jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(act_spec, P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(act_spec, P()),
        check_vma=False,
    )(x, params["router"], params["w_in"], params["w_gate"], params["w_out"])
    return out, aux


def _spec_dim_size(mesh, spec: P, dim: int) -> int:
    """Product of mesh-axis sizes sharding dimension `dim` of `spec`."""
    if dim >= len(spec):
        return 1
    entry = spec[dim]
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size
