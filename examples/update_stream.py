"""Streaming corpus updates through the index-artifact lifecycle.

    PYTHONPATH=src python examples/update_stream.py

The walkthrough of DESIGN.md SS10, insert -> serve -> compact:

1. build an ``IndexArtifact`` over a synthetic catalogue and stand up a
   live ``ReverseServer`` ("which users would see this item in their
   top-k?") from it;
2. a batch of trending items lands: ``insert_items`` stages them in the
   fixed-capacity delta buffer and ``swap`` makes the new version live
   between flushes — pending tickets survive, answers reflect the new
   rows immediately, and the engine pays at most ONE extra compile ever
   (the buffer's capacity is a static shape);
3. retire a few items with ``delete_items`` — the swap reuses every
   compiled executable (delete-only churn rides the plain pipeline);
4. ``compact()`` folds the stream into fresh norm-ordered partitions: the
   compacted artifact answers bitwise like a cold build on the mutated
   catalogue, and ``save``/``load`` round-trips it for the next process
   (on any mesh — attach does the placement).
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import IndexArtifact, RkMIPSEngine, get_config
from repro.data import synthetic


def audience(result) -> int:
    return int(np.asarray(result.predictions).sum())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-items", type=int, default=4096)
    ap.add_argument("--m-users", type=int, default=8192)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--inserts", type=int, default=24)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    ki, kq, kb, kn = jax.random.split(key, 4)
    items, users = synthetic.recommendation_data(
        ki, args.n_items, args.m_users, args.dim)
    promoted = synthetic.queries_from_items(kq, items, 4)

    cfg = get_config("sah").replace(delta_capacity=max(64, args.inserts),
                                    serve_batch_size=4)
    art = IndexArtifact.build(items, users, kb, config=cfg)
    eng = RkMIPSEngine.from_artifact(art)
    server = eng.reverse_server()
    print(f"built v1: {art.n_base} items x {art.n_users} users, "
          f"fingerprint {art.fingerprint[:16]}...")

    # -- serve against the base version -----------------------------------
    server.submit(promoted)
    base = server.flush(args.k)
    print(f"v1: audiences {[audience(r) for r in base]} "
          f"(compiles={server.compile_count})")

    # -- trending items arrive: stage + hot swap --------------------------
    # make them compete: in-distribution blends of catalogue rows, boosted
    pick = jax.random.randint(kn, (2, args.inserts), 0, args.n_items)
    trending = 0.65 * (items[pick[0]] + items[pick[1]])
    art_v2 = art.insert_items(trending)
    server.submit(promoted)                      # tickets before the swap
    server.swap(art_v2)                          # ...survive it
    v2 = server.flush(args.k)
    print(f"v2 (+{args.inserts} staged rows): audiences "
          f"{[audience(r) for r in v2]} (compiles={server.compile_count}, "
          f"delta buffer {int(np.asarray(art_v2.delta_mask).sum())}"
          f"/{art_v2.delta_capacity})")
    shrink = sum(audience(a) < audience(b) for a, b in zip(v2, base))
    print(f"    {shrink}/4 promoted items lost audience to the staged "
          f"rows — inserts are live before any rebuild")

    # -- retire the weakest catalogue rows: delete-only churn is free -----
    norms = np.asarray(jnp.linalg.norm(items, axis=-1))
    retired = np.argsort(norms)[:8].tolist()
    art_v3 = art_v2.delete_items(retired)
    server.swap(art_v3)
    server.submit(promoted[0])
    one = server.flush(args.k)[0]
    print(f"v3 (-{len(retired)} retired): audience {audience(one)} "
          f"(compiles={server.compile_count})")

    # -- compact: fold the stream into fresh partitions -------------------
    art_v4 = art_v3.compact()
    server.swap(art_v4)
    ref = RkMIPSEngine(cfg).build(art_v3.effective_items(), users, kb)
    check = RkMIPSEngine.from_artifact(art_v4).query_batch(promoted, args.k)
    truth = ref.query_batch(promoted, args.k)
    assert np.array_equal(np.asarray(check.predictions),
                          np.asarray(truth.predictions))
    print(f"v4 compacted: {art_v4.n_base} rows, bitwise equal to a cold "
          f"build on the mutated catalogue")

    # -- ship it ----------------------------------------------------------
    with tempfile.TemporaryDirectory() as d:
        art_v4.save(d)
        back = IndexArtifact.load(d)
        assert back.fingerprint == art_v4.fingerprint
        print(f"saved + loaded, fingerprint {back.fingerprint[:16]}... "
              f"verified — attach it to any engine, on any mesh")


if __name__ == "__main__":
    main()
