from repro.configs.base import (ArchSpec, ShapeSpec, all_archs, get,  # noqa
                                register)
