"""LM training driver with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py --model 100m --steps 200

--model 100m is a ~100M-parameter dense transformer (the task's end-to-end
training target); --model tiny runs in seconds for CI. Resumes automatically
from --ckpt-dir; --fail-at N simulates a worker crash to exercise recovery.
"""

import argparse

import jax
import jax.numpy as jnp

from repro.data import synthetic
from repro.models import transformer as tf_lib
from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt_lib
from repro.train.trainer import TrainState, make_train_step, train_loop

MODELS = {
    "tiny": tf_lib.LMConfig(
        name="tiny", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_head=32, d_ff=512, vocab=2048, dtype=jnp.float32, attn_chunk=64),
    # ~100M params: 12L x 640d, vocab 32k
    "100m": tf_lib.LMConfig(
        name="100m", n_layers=12, d_model=640, n_heads=10, n_kv_heads=5,
        d_head=64, d_ff=2560, vocab=32768, dtype=jnp.float32,
        attn_chunk=128),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=MODELS, default="tiny")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()

    cfg = MODELS[args.model]
    print(f"model={cfg.name} params~{cfg.n_params/1e6:.1f}M")
    key = jax.random.PRNGKey(0)
    opt = opt_lib.chain(opt_lib.clip_by_global_norm(1.0),
                        opt_lib.adamw(opt_lib.cosine_schedule(
                            3e-4, warmup=20, total=args.steps)))
    step = make_train_step(lambda p, b: tf_lib.lm_loss(p, b, cfg), opt,
                           grad_accum=args.grad_accum)

    params = tf_lib.init_params(key, cfg)
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))

    # resume if a checkpoint exists (deterministic, step-indexed data)
    if args.ckpt_dir:
        last = ckpt_lib.latest_step(args.ckpt_dir)
        if last is not None:
            state, _ = ckpt_lib.restore(args.ckpt_dir, last, state)
            print(f"resumed from step {last}")

    data = synthetic.lm_token_batches(jax.random.PRNGKey(1), args.batch,
                                      args.seq, cfg.vocab)
    state = train_loop(state, step, data, n_steps=args.steps,
                       ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       log_every=10, fail_at_step=args.fail_at,
                       metadata={"model": cfg.name})
    print(f"done at step {int(state.step)}")


if __name__ == "__main__":
    main()
