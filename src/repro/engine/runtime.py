"""Threaded serving runtime: ticket pipeline + background compaction.

DESIGN.md SS12 is the contract. ``engine/serving.py`` gives the repo
micro-batched serving as a *library* — callers submit tickets and then
flush on their own thread, and ``IndexArtifact.compact()`` stops the world
to rebuild. This module is the missing *loop*: a ``ServingRuntime`` wraps
either server in a thread pipeline so submitters get futures, flushes
happen off the caller's thread, and compaction runs in the background and
hot-swaps in between flushes.

Architecture (one runtime = up to three thread roles + the callers):

  callers ──submit──> admission deque ──workers──> dispatch ──> completion
                                            │         queue        thread
                                            │ (dispatch lock)        │
  maintenance thread ──compact off-thread──swap                  futures set

  * **admission**: ``submit`` validates the query up front
    (``serving.validate_query_rows``), enqueues one ``ServeTicket`` per
    row, and returns immediately — the ticket is a future
    (``result(timeout=)`` blocks, ``done()`` polls).
  * **workers** drain the queue into micro-batches of the server's
    ``serve_batch_size``: a batch is the longest run of queue-head tickets
    sharing one ``(k, n_cand, scan)`` signature, so every dispatch goes
    through the server's own ``_flush_batch`` — the *same* code path the
    synchronous ``flush`` uses, with the same padding. Runtime answers are
    therefore bitwise identical to library-mode serving, and compile
    counts stay pinned at one per batch shape (partial batches pad, they
    never shrink the shape). With a bucket ladder configured
    (``EngineConfig.serve_buckets``, DESIGN.md SS14) a partial run pads
    only up to the nearest rung (``server.bucket_for``) instead of the
    full batch — fewer dead rows per dispatch, same bitwise answers —
    and a run already sitting on a rung skips the linger entirely.
    ``ServingRuntime(warmup=True)`` precompiles every rung's executable
    before the first ticket, so bucketing never *adds* traces at
    runtime: ``stats.traces_after_warmup`` stays 0.
  * the **completion queue** decouples dispatch from reply: workers hand
    finished batches to a completion thread that resolves the futures, so
    a slow consumer can never stall the dispatch loop.
  * the **maintenance thread** (``compaction=True``) watches the live
    artifact's delta buffer; past ``compact_fill`` (or on
    ``request_compaction()``) it snapshots the live version, builds the
    next base off-thread via the staged build pipeline
    (``IndexArtifact.compact(policy=...)`` — XLA releases the GIL, so
    dispatch keeps flowing), then re-stages any churn that raced the build
    (``artifact.reconcile_compaction``) and ``swap()``s the result in
    under the dispatch lock — between flushes, never during one. With
    ``artifact_dir`` set, each compacted version is persisted with the
    ``keep=`` GC policy (the just-saved step is always protected).

Locking discipline (deadlock-free by ordering):

  * ``_admit`` (condition) guards the ticket deque + counters;
  * ``_dispatch_lock`` serializes batch dispatch with ``swap`` — a flush
    and a swap can never interleave, which is what "pending tickets
    survive a swap" means under threads;
  * ``_mutate_lock`` serializes artifact-version edits (staging mutations
    vs. compaction reconcile). Lock order is always mutate -> dispatch;
    workers take only the dispatch lock.

Deadlines: a ticket carries an optional wall-clock budget. Expiry is
checked at batch-formation time — an expired ticket is failed with
``TicketExpired`` *before* dispatch (in-flight batches are never
interrupted; XLA dispatches are not cancellable), so one stalled consumer
or a deep backlog can't wedge every later ticket behind work nobody
wants. Per-batch, expiry costs one clock read.

``drain()`` blocks until every admitted ticket has resolved; ``close()``
drains (optional), stops the threads, and fails whatever is left —
afterwards ``submit`` raises. The runtime is a context manager.
"""

from __future__ import annotations

import collections
import queue as _queue
import threading
import time
from typing import NamedTuple

from repro.engine import artifact as _artifact
from repro.engine import serving as _serving

_UNSET = object()
_SHUTDOWN = object()


class TicketExpired(TimeoutError):
    """The ticket's deadline passed before its batch was dispatched."""


class ServeTicket:
    """One admitted query's future.

    ``result(timeout=)`` blocks until the runtime resolves the ticket and
    returns the server's answer (``ServeResult``/``ReverseResult``) or
    raises what dispatch raised (``TicketExpired`` after a missed
    deadline). ``done()`` polls. Tickets resolve exactly once; ``seq`` is
    the admission sequence number (tickets dispatch in ``seq`` order per
    signature run, and results never cross tickets — pinned by
    tests/test_runtime.py).
    """

    __slots__ = ("query", "k", "n_cand", "scan", "seq", "deadline",
                 "submitted_at", "done_at", "_event", "_value", "_error")

    def __init__(self, query, k: int, n_cand, scan, seq: int,
                 deadline: float | None):
        self.query = query
        self.k = k
        self.n_cand = n_cand
        self.scan = scan
        self.seq = seq
        self.deadline = deadline          # absolute monotonic time or None
        self.submitted_at = time.perf_counter()
        self.done_at: float | None = None
        self._event = threading.Event()
        self._value = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None):
        """The answer, blocking up to ``timeout`` seconds for it."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"ticket {self.seq} not resolved within "
                               f"{timeout}s")
        if self._error is not None:
            raise self._error
        return self._value

    def exception(self, timeout: float | None = None):
        """The dispatch error (None on success), blocking like result()."""
        if not self._event.wait(timeout):
            raise TimeoutError(f"ticket {self.seq} not resolved within "
                               f"{timeout}s")
        return self._error

    @property
    def latency(self) -> float | None:
        """Submit-to-resolve wall seconds; None while unresolved."""
        return None if self.done_at is None else \
            self.done_at - self.submitted_at

    def _resolve(self, value=None, error: BaseException | None = None):
        self._value = value
        self._error = error
        self.done_at = time.perf_counter()
        self._event.set()

    def __repr__(self) -> str:
        state = ("done" if self._error is None else
                 type(self._error).__name__) if self.done() else "pending"
        return f"ServeTicket(seq={self.seq}, k={self.k}, {state})"


class RuntimeStats(NamedTuple):
    """Counters snapshot (``ServingRuntime.stats``), monotone per runtime:
    every submitted ticket ends as exactly one of completed / expired /
    failed.

    The last three make warmup/bucketing regressions observable rather
    than inferred (DESIGN.md SS14): ``bucket_hits`` counts successful
    dispatches padded to a sub-maximal ladder rung (0 without
    ``serve_buckets``), ``bucket_pad_rows`` totals the dead padding rows
    those dispatches added (padding waste is measurable, not guessed),
    and ``traces_after_warmup`` is how many XLA traces the server's
    dispatch has cost since the warmup baseline (construction, or the
    last ``warmup()``) — a warmed runtime must hold it at 0, which CI
    asserts via benchmarks/bench_load.py.

    ``truncated`` counts tickets whose answer a scan budget
    (``EngineConfig.scan_budget``) resolved conservatively — the
    per-ticket ``ReverseResult.truncated`` flag aggregated per runtime,
    so budget pressure is attributable per tenant (DESIGN.md SS15),
    never silent."""

    submitted: int
    completed: int
    expired: int      # deadline missed before dispatch (TicketExpired)
    failed: int       # dispatch raised, or runtime closed undrained
    batches: int      # successful micro-batch dispatches
    swaps: int        # artifact versions made live
    compactions: int  # background compact->reconcile->swap cycles
    bucket_hits: int      # dispatches padded to a sub-max ladder rung
    bucket_pad_rows: int  # dead rows added by bucket padding
    traces_after_warmup: int  # server traces since the warmup baseline
    truncated: int    # tickets answered under an exhausted scan budget


class WorkerPool:
    """Shared dispatch workers for many ``ServingRuntime``s (the gateway
    tier, DESIGN.md SS15).

    A runtime constructed with ``pool=`` starts no worker threads of its
    own; instead the pool's threads round-robin over every registered
    runtime, forming and dispatching micro-batches through each one's own
    ``_try_next_batch`` / ``_dispatch_batch`` — the exact code path a
    dedicated worker would take, so pooled answers are bitwise identical
    to dedicated-runtime answers.

    Non-stall contract: a pool thread takes a runtime's dispatch lock
    with ``acquire(blocking=False)`` — if one tenant's lock is held (a
    hot-swap, a compaction landing, another pool thread mid-flush), the
    thread moves on to the next tenant instead of queueing behind it.
    One tenant's maintenance can therefore never stall another tenant's
    flushes (pinned by tests/test_gateway.py).
    """

    def __init__(self, workers: int = 1, *, poll_interval: float = 0.01):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self._cond = threading.Condition()
        self._members: list["ServingRuntime"] = []
        self._rr = 0
        self._stop = threading.Event()
        self._threads = [
            threading.Thread(target=self._run, name=f"pool-worker-{i}",
                             daemon=True)
            for i in range(workers)]
        self._poll = poll_interval
        for t in self._threads:
            t.start()

    def register(self, runtime: "ServingRuntime") -> None:
        with self._cond:
            if self._stop.is_set():
                raise RuntimeError("worker pool is closed")
            if runtime not in self._members:
                self._members.append(runtime)
            self._cond.notify_all()

    def unregister(self, runtime: "ServingRuntime") -> None:
        with self._cond:
            if runtime in self._members:
                self._members.remove(runtime)

    def notify(self) -> None:
        """Wake the pool: a member admitted tickets."""
        with self._cond:
            self._cond.notify_all()

    def _run(self) -> None:
        while not self._stop.is_set():
            with self._cond:
                members = list(self._members)
                start = self._rr
                self._rr = (self._rr + 1) % max(1, len(members))
            dispatched = False
            for i in range(len(members)):
                rt = members[(start + i) % len(members)]
                # non-blocking: a busy/swapping tenant is skipped, not
                # queued behind — the cross-tenant non-stall guarantee
                if not rt._dispatch_lock.acquire(blocking=False):
                    continue
                try:
                    batch = rt._try_next_batch()
                    if batch is None:
                        continue
                    dispatched = True
                    try:
                        results, pad_to = rt._dispatch_batch(batch)
                    except BaseException as e:  # noqa: BLE001 — to futures
                        rt._completion.put((batch, None, e, None))
                    else:
                        rt._completion.put((batch, results, None, pad_to))
                finally:
                    rt._dispatch_lock.release()
            if not dispatched:
                with self._cond:
                    self._cond.wait(self._poll)

    def close(self) -> None:
        """Stop the pool threads. Registered runtimes must be closed (or
        re-homed) first — a pooled runtime with live tickets and no pool
        would never dispatch them."""
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        for t in self._threads:
            t.join(timeout=30)

    def __enter__(self) -> "WorkerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class ServingRuntime:
    """The threaded serving loop over a ``RetrievalServer`` or
    ``ReverseServer`` (module docstring; DESIGN.md SS12).

    Parameters:
      server        the wrapped server; its ``serve_batch_size`` is the
                    micro-batch size, its ``_flush_batch`` the dispatch.
      k             default k for ``submit`` (submit's ``k=`` overrides;
                    one of the two must be given).
      workers       dispatch worker threads. Dispatch itself is
                    serialized by the dispatch lock (one executable, one
                    device stream); extra workers only overlap batch
                    formation with dispatch, so the default of 1 is right
                    unless profiling says otherwise.
      deadline      default per-ticket budget in wall seconds (None: no
                    deadline). A ticket that waits longer is failed with
                    ``TicketExpired`` instead of dispatched.
      batch_linger  how long (seconds) a worker waits for a partial batch
                    to fill before dispatching it anyway — the classic
                    throughput/latency knob. With a bucket ladder
                    (``EngineConfig.serve_buckets``) a run whose length
                    already sits exactly on a rung skips the linger: it
                    can dispatch immediately with zero padding, so
                    waiting buys nothing.
      warmup        ahead-of-time compile every serving dispatch cell
                    before the worker threads start (DESIGN.md SS14):
                    calls ``server.warmup(warmup_ks)`` and then baselines
                    ``traces_after_warmup`` at 0 — the first request at
                    any ladder rung runs an already-built executable.
      warmup_ks     the ks warmup compiles for (default: the runtime's
                    ``k=``; warmup with neither raises).
      compaction    start the maintenance thread (requires an
                    artifact-backed server).
      compact_fill  delta-buffer fill fraction that triggers a background
                    compaction (``request_compaction()`` forces one).
      compact_policy ``ShardingPolicy`` for the off-thread rebuild
                    (default: the server's / engine's own policy).
      artifact_dir  persist each compacted version here (``save(step=n)``
                    with monotonically increasing steps).
      keep          GC/retention: prune the ``artifact_dir`` history to
                    the newest ``keep`` versions after each save (the
                    just-saved version is always protected).
      poll_interval idle-thread wakeup period in seconds (responsiveness
                    of compaction-trigger checks and close()).
      pool          a shared ``WorkerPool`` to dispatch through instead
                    of starting dedicated worker threads (``workers`` is
                    then ignored). The pool's threads run the same batch
                    formation and dispatch path, so answers are bitwise
                    identical; close() unregisters from the pool but
                    leaves it running for its other members.
    """

    def __init__(self, server, *, k: int | None = None, workers: int = 1,
                 deadline: float | None = None, batch_linger: float = 0.002,
                 warmup: bool = False, warmup_ks=None,
                 compaction: bool = False, compact_fill: float = 0.5,
                 compact_policy=None, artifact_dir: str | None = None,
                 keep: int | None = None, poll_interval: float = 0.05,
                 pool: "WorkerPool | None" = None):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if not 0.0 < compact_fill <= 1.0:
            raise ValueError(f"compact_fill must be in (0, 1], got "
                             f"{compact_fill}")
        self.server = server
        self._engine = getattr(server, "engine", None)
        self._is_reverse = self._engine is not None
        self.artifact = (self._engine.artifact if self._is_reverse
                         else server.artifact)
        if compaction and self.artifact is None:
            raise ValueError(
                "compaction=True needs an artifact-backed server: build "
                "the server from_artifact / engine.from_artifact so the "
                "runtime has a version to watch and swap")
        if keep is not None and artifact_dir is None:
            raise ValueError("keep= (artifact GC) needs artifact_dir=")
        self._default_k = k
        self._default_deadline = deadline
        self._linger = batch_linger
        self._poll = poll_interval
        self._compact_fill = compact_fill
        self._compact_policy = compact_policy if compact_policy is not None \
            else (self._engine.policy if self._is_reverse
                  else server.policy)
        self._artifact_dir = artifact_dir
        self._keep = keep
        self._save_step = 0

        self._admit = threading.Condition()
        self._ticket_deque: collections.deque[ServeTicket] = \
            collections.deque()
        self._dispatch_lock = threading.Lock()
        self._mutate_lock = threading.Lock()
        self._completion: _queue.SimpleQueue = _queue.SimpleQueue()
        self._stop = threading.Event()
        self._closed = False
        self._seq = 0
        self._unfinished = 0
        self._submitted = 0
        self._completed = 0
        self._expired = 0
        self._failed = 0
        self._batches = 0
        self._swaps = 0
        self._compactions = 0
        self._bucket_hits = 0
        self._bucket_pad_rows = 0
        self._truncated = 0
        self._pool = pool
        self._linger_until: float | None = None   # pooled-linger deadline
        self.last_compaction_seconds: float | None = None

        # AOT warmup runs before any worker exists, so no ticket can race
        # a live trace; the baseline makes traces_after_warmup read 0
        # until something actually traces post-warmup. Without warmup the
        # baseline is construction time: the counter then reads "traces
        # this runtime caused", the cold-start number bench_load reports.
        if warmup:
            ks = warmup_ks if warmup_ks is not None else \
                ([] if k is None else [k])
            if not ks:
                raise ValueError("warmup=True needs warmup_ks= (or a "
                                 "default k= to warm for)")
            server.warmup(tuple(ks))
        self._trace_base = server.compile_count

        # Pooled mode (DESIGN.md SS15): the runtime starts no dispatch
        # workers of its own — the shared WorkerPool's threads form and
        # dispatch its batches. Completion and maintenance threads stay
        # per-runtime (cheap, and their state is per-tenant anyway).
        self._threads = [] if pool is not None else [
            threading.Thread(target=self._worker_loop,
                             name=f"serve-worker-{i}", daemon=True)
            for i in range(workers)]
        self._completer = threading.Thread(target=self._completion_loop,
                                           name="serve-completer",
                                           daemon=True)
        self._compact_wake = threading.Event()
        self._compact_forced = threading.Event()
        self._compactor = None
        if compaction:
            self._compactor = threading.Thread(
                target=self._maintenance_loop, name="serve-compactor",
                daemon=True)
        self._completer.start()
        for t in self._threads:
            t.start()
        if self._compactor is not None:
            self._compactor.start()
        if pool is not None:
            pool.register(self)

    # -- admission ---------------------------------------------------------

    def submit(self, q, *, k: int | None = None, n_cand: int | None = None,
               scan: str | None = None, deadline=_UNSET):
        """Admit a query (d,) -> its ``ServeTicket``; a block (nq, d) ->
        one ticket per row, resolved independently.

        Validation (dtype/shape/dimensionality) happens here, before the
        queue — a malformed query raises ``ValueError`` and nothing is
        admitted. ``k``/``deadline`` default to the runtime's;
        ``n_cand``/``scan`` are forward-server knobs (tickets dispatch in
        same-signature micro-batches, so mixing knobs costs batch
        fragmentation, not correctness). Raises ``RuntimeError`` once the
        runtime is closed.
        """
        q = _serving.validate_query_rows(q, self.server._dim,
                                         "runtime.submit")
        k = self._default_k if k is None else k
        if k is None:
            raise ValueError("no k for this ticket: pass submit(..., k=) "
                             "or construct ServingRuntime(..., k=)")
        if self._is_reverse and (n_cand is not None or scan is not None):
            raise ValueError("n_cand/scan are forward-serving knobs; the "
                             "reverse pipeline has no per-ticket override")
        budget = self._default_deadline if deadline is _UNSET else deadline
        expiry = None if budget is None else time.monotonic() + budget
        rows = [q] if q.ndim == 1 else [q[i] for i in range(q.shape[0])]
        with self._admit:
            if self._closed:
                raise RuntimeError("runtime is closed: no new tickets "
                                   "(create a new ServingRuntime)")
            tickets = []
            for row in rows:
                t = ServeTicket(row, k, n_cand, scan, self._seq, expiry)
                self._seq += 1
                self._ticket_deque.append(t)
                tickets.append(t)
            self._submitted += len(tickets)
            self._unfinished += len(tickets)
            self._admit.notify_all()
        if self._pool is not None:
            self._pool.notify()
        return tickets[0] if q.ndim == 1 else tickets

    # -- worker / completion loops -----------------------------------------

    def _signature(self, t: ServeTicket) -> tuple:
        return (t.k, t.n_cand, t.scan)

    def _ladder(self) -> tuple:
        """The live config's bucket ladder (ascending dispatch sizes) —
        read per call, so a config swapped between flushes brings its own
        ladder along, like ``batch_size``."""
        cfg = (self._engine.config if self._is_reverse
               else self.server.config)
        return cfg.bucket_ladder()

    def _form_batch(self) -> list[ServeTicket]:
        """Pop the next signature run off the deque — the longest run of
        queue-head tickets sharing one signature, up to
        ``serve_batch_size``. Expired tickets are failed here,
        pre-dispatch. Caller holds ``_admit``. [] = nothing poppable."""
        size = self.server.batch_size
        batch: list[ServeTicket] = []
        sig = None
        now = time.monotonic()
        while self._ticket_deque and len(batch) < size:
            head = self._ticket_deque[0]
            if head.deadline is not None and now >= head.deadline:
                self._ticket_deque.popleft()
                self._completion.put(([head], None, TicketExpired(
                    f"ticket {head.seq} missed its deadline "
                    f"before dispatch"), None))
                continue
            if sig is None:
                sig = self._signature(head)
            elif self._signature(head) != sig:
                break
            batch.append(self._ticket_deque.popleft())
        return batch

    def _next_batch(self) -> list[ServeTicket] | None:
        """Blocking batch formation for this runtime's own workers.
        None = stopping and queue empty."""
        with self._admit:
            lingered = False
            while True:
                if not self._ticket_deque:
                    if self._stop.is_set():
                        return None
                    self._admit.wait(self._poll)
                    lingered = False
                    continue
                if (self._linger > 0 and not lingered
                        and len(self._ticket_deque) < self.server.batch_size
                        and len(self._ticket_deque) not in self._ladder()
                        and not self._stop.is_set()):
                    # one bounded wait for a fuller batch, then dispatch
                    # whatever is there — never a second linger. A queue
                    # already sitting exactly on a ladder rung skips the
                    # wait: it dispatches with zero padding, so lingering
                    # buys throughput nothing and costs latency.
                    lingered = True
                    self._admit.wait(self._linger)
                    continue
                batch = self._form_batch()
                if batch:
                    return batch
                lingered = False  # head tickets all expired; go around

    def _try_next_batch(self) -> list[ServeTicket] | None:
        """Non-blocking batch formation for pooled workers (the caller —
        a ``WorkerPool`` thread — already holds this runtime's dispatch
        lock). Returns None when the queue is empty or still lingering
        for a fuller batch; the linger is a deadline (``_linger_until``)
        rather than a sleep, so a pool thread never blocks on one tenant
        while others have work."""
        with self._admit:
            n = len(self._ticket_deque)
            if n == 0:
                self._linger_until = None
                return None
            if (self._linger > 0
                    and n < self.server.batch_size
                    and n not in self._ladder()
                    and not self._stop.is_set()):
                now = time.monotonic()
                if self._linger_until is None:
                    self._linger_until = now + self._linger
                    return None
                if now < self._linger_until:
                    return None
            self._linger_until = None
            return self._form_batch() or None

    def _dispatch_batch(self, batch: list[ServeTicket]) -> tuple[list, int]:
        """Dispatch one signature run through the server's own flush path,
        padded to the nearest ladder rung (``bucket_for``) rather than the
        full ``serve_batch_size`` — bitwise the same answers (padding is
        dead), one executable per rung, all precompiled by warmup.
        Returns (results, pad_to)."""
        first = batch[0]
        group = [t.query for t in batch]
        pad_to = self.server.bucket_for(len(group))
        if self._is_reverse:
            return (self.server._flush_batch(group, first.k,
                                             pad_to=pad_to), pad_to)
        return (self.server._flush_batch(group, first.k,
                                         n_cand=first.n_cand,
                                         scan=first.scan,
                                         pad_to=pad_to), pad_to)

    def _worker_loop(self) -> None:
        while True:
            batch = self._next_batch()
            if batch is None:
                return
            try:
                with self._dispatch_lock:
                    results, pad_to = self._dispatch_batch(batch)
            except BaseException as e:  # noqa: BLE001 — routed to futures
                self._completion.put((batch, None, e, None))
                continue
            self._completion.put((batch, results, None, pad_to))

    def _completion_loop(self) -> None:
        while True:
            item = self._completion.get()
            if item is _SHUTDOWN:
                return
            batch, results, error, pad_to = item
            if error is not None:
                for t in batch:
                    t._resolve(error=error)
            else:
                for t, r in zip(batch, results):
                    t._resolve(value=r)
            with self._admit:
                self._unfinished -= len(batch)
                if error is None:
                    self._completed += len(batch)
                    self._batches += 1
                    self._truncated += sum(
                        1 for r in results
                        if getattr(r, "truncated", False))
                    if pad_to is not None:
                        if pad_to < self.server.batch_size:
                            self._bucket_hits += 1
                        self._bucket_pad_rows += pad_to - len(batch)
                elif isinstance(error, TicketExpired):
                    self._expired += len(batch)
                else:
                    self._failed += len(batch)
                self._admit.notify_all()

    # -- artifact lifecycle ------------------------------------------------

    def _require_artifact(self) -> "_artifact.IndexArtifact":
        if self.artifact is None:
            raise RuntimeError("runtime has no artifact: build the server "
                               "from an IndexArtifact to stream mutations")
        return self.artifact

    def _swap_live(self, artifact) -> None:
        # caller holds _mutate_lock; the dispatch lock is what makes the
        # swap land *between* flushes
        with self._dispatch_lock:
            self.server.swap(artifact)
            self.artifact = artifact
            with self._admit:
                self._swaps += 1

    def swap(self, artifact) -> None:
        """Make an externally built artifact version live, between
        flushes; pending tickets survive and are answered against it."""
        with self._mutate_lock:
            self._swap_live(artifact)

    def insert_items(self, rows) -> "_artifact.IndexArtifact":
        """Stage rows into the live version's delta buffer and swap the
        new version in (between flushes). Returns the new version."""
        with self._mutate_lock:
            art = self._require_artifact().insert_items(rows)
            self._swap_live(art)
        self._compact_wake.set()   # let the compactor re-check the fill
        return art

    def delete_items(self, ids) -> "_artifact.IndexArtifact":
        """Retire rows on the live version and swap the new version in
        (between flushes). Returns the new version."""
        with self._mutate_lock:
            art = self._require_artifact().delete_items(ids)
            self._swap_live(art)
        self._compact_wake.set()
        return art

    def request_compaction(self) -> None:
        """Ask the maintenance thread for a compaction now, regardless of
        fill (no-op without ``compaction=True`` or pending churn)."""
        self._compact_forced.set()
        self._compact_wake.set()

    def _maintenance_loop(self) -> None:
        while not self._stop.is_set():
            self._compact_wake.wait(self._poll)
            self._compact_wake.clear()
            if self._stop.is_set():
                return
            snapshot = self.artifact
            if snapshot is None or not snapshot.has_pending:
                self._compact_forced.clear()
                continue
            fill = snapshot.delta_used / snapshot.delta_capacity
            if not (self._compact_forced.is_set()
                    or fill >= self._compact_fill):
                continue
            self._compact_forced.clear()
            t0 = time.perf_counter()
            # the slow part runs unlocked: traffic keeps flushing, and
            # mutations keep staging onto descendants of `snapshot`
            compacted = snapshot.compact(policy=self._compact_policy)
            with self._mutate_lock:
                merged = _artifact.reconcile_compaction(
                    snapshot, self.artifact, compacted)
                self._swap_live(merged)
                with self._admit:
                    self._compactions += 1
            self.last_compaction_seconds = time.perf_counter() - t0
            if self._artifact_dir is not None:
                step = self._save_step
                self._save_step += 1
                merged.save(self._artifact_dir, step=step, keep=self._keep)

    # -- lifecycle ---------------------------------------------------------

    def warmup(self, ks=None, **server_kwargs) -> int:
        """Re-run the server's AOT warmup under the dispatch lock (never
        mid-flush) and re-baseline ``traces_after_warmup`` at 0 — e.g.
        after swapping in a config with a different ladder, or to warm
        extra ks mid-flight. ``ks`` defaults to the runtime's ``k=``;
        extra keyword args go to ``server.warmup`` (n_cands/scans/buckets
        on the forward server, buckets on the reverse). Returns the
        number of cells compiled."""
        ks = ks if ks is not None else \
            ([] if self._default_k is None else [self._default_k])
        if not ks:
            raise ValueError("warmup needs ks= (or a default k= on the "
                             "runtime)")
        with self._dispatch_lock:
            cells = self.server.warmup(tuple(ks), **server_kwargs)
            self._trace_base = self.server.compile_count
        return cells

    def rebaseline_traces(self) -> None:
        """Zero ``traces_after_warmup`` at the server's current compile
        count. The gateway's gateway-wide warmup uses this: tenants that
        share a compiled dispatch are warmed once through a single
        representative, then every sharer is re-baselined — so
        ``traces_after_warmup == 0`` holds across all tenants without
        per-tenant re-tracing (DESIGN.md SS15)."""
        with self._dispatch_lock:
            self._trace_base = self.server.compile_count

    @property
    def stats(self) -> RuntimeStats:
        """A consistent snapshot of the runtime counters (see
        ``RuntimeStats`` for the field contract; ``traces_after_warmup``
        is derived live from the server's ``compile_count`` against the
        warmup baseline)."""
        traces = self.server.compile_count - self._trace_base
        with self._admit:
            return RuntimeStats(self._submitted, self._completed,
                                self._expired, self._failed, self._batches,
                                self._swaps, self._compactions,
                                self._bucket_hits, self._bucket_pad_rows,
                                traces, self._truncated)

    @property
    def pending(self) -> int:
        """Tickets admitted but not yet resolved (queued + in flight)."""
        with self._admit:
            return self._unfinished

    def drain(self, timeout: float | None = None) -> bool:
        """Block until every admitted ticket has resolved (completed,
        expired, or failed). True on fully drained; False on timeout."""
        end = None if timeout is None else time.monotonic() + timeout
        with self._admit:
            while self._unfinished > 0:
                remaining = self._poll if end is None \
                    else end - time.monotonic()
                if remaining <= 0:
                    return False
                self._admit.wait(min(remaining, self._poll))
            return True

    def close(self, *, drain: bool = True,
              timeout: float | None = None) -> None:
        """Stop the runtime: refuse new tickets, optionally ``drain()``,
        stop and join every thread, and fail whatever is left undispatched
        (so no future ever hangs). Idempotent."""
        with self._admit:
            already = self._closed
            self._closed = True
        if not already and drain:
            self.drain(timeout)
        self._stop.set()
        self._compact_wake.set()
        with self._admit:
            self._admit.notify_all()
        for t in self._threads:
            t.join(timeout=30)
        if self._compactor is not None:
            self._compactor.join(timeout=60)
        if self._pool is not None:
            # Unregister, then take the dispatch lock once: pool threads
            # form batches only while holding it, so after this no pooled
            # worker can race the leftover sweep below.
            self._pool.unregister(self)
            with self._dispatch_lock:
                pass
        with self._admit:
            leftover = list(self._ticket_deque)
            self._ticket_deque.clear()
        if leftover:
            self._completion.put((leftover, None, RuntimeError(
                "runtime closed before these tickets were dispatched"),
                None))
        if self._completer.is_alive():
            self._completion.put(_SHUTDOWN)
            self._completer.join(timeout=30)

    def __enter__(self) -> "ServingRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))
