"""IndexArtifact lifecycle (engine/artifact.py, DESIGN.md SS10).

Pins the artifact contracts: (1) build/attach is bit-for-bit the legacy
in-engine build; (2) save/load round-trips through the SS6 checkpoint
machinery with a verified content fingerprint, and a loaded artifact
attaches onto any ShardingPolicy (the 8-device -> 2x2 mesh change runs in a
subprocess); (3) streaming corpus deltas — staged inserts are exactly
scanned, deletions leave every scan, and for exact-scan configs pre-compact
predictions are bitwise a from-scratch build on the mutated corpus (the
hypothesis-drawn version lives in tests/test_core_properties.py);
``compact()`` is bitwise a from-scratch build for every config; (4) churn
never re-traces: the delta buffer costs one executable ever, delete-only
churn costs zero, and hot swaps of same-shape versions cost zero on both
servers while pending tickets survive.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exact, sah
from repro.data import synthetic
from repro.engine import (IndexArtifact, RetrievalServer, RkMIPSEngine,
                          get_config, load_artifact)

D = 16


@pytest.fixture(scope="module")
def workload():
    key = jax.random.PRNGKey(23)
    ki, kq = jax.random.split(key)
    items, users = synthetic.recommendation_data(ki, 120, 64, D)
    queries = synthetic.queries_from_items(kq, items, 4)
    return items, users, queries


def _cfg(scan):
    return get_config("sah").replace(tile=32, n_bits=32, k_max=8, n_top=8,
                                     leaf_size=8, n_cand=16, scan=scan,
                                     delta_capacity=8, serve_batch_size=2)


_BUILD_KEY = jax.random.PRNGKey(31)
_LOGICAL = ("blocks_alive", "users_alive", "n_no_lb", "n_yes_norm", "n_scan")


def _mutate(art, items, key):
    """A canonical mutation: 5 staged inserts, deletions hitting the base
    corpus, a P' member (highest-norm item), and one staged row. Returns
    (new artifact, the equivalent from-scratch corpus)."""
    rows = jax.random.normal(key, (5, D)) * 1.2
    top_id = int(jnp.argmax(jnp.linalg.norm(items, axis=-1)))
    dels = sorted({0, 7, 55, top_id})
    a = art.insert_items(rows).delete_items(dels + [items.shape[0] + 1])
    keep = np.setdiff1d(np.arange(items.shape[0]), dels)
    mutated = jnp.concatenate([items[keep], rows[np.asarray([0, 2, 3, 4])]])
    return a, mutated


def test_build_attach_parity_and_value_semantics(workload):
    """from_artifact == legacy engine.build == raw core, bit for bit; and
    staging deltas returns a NEW version, leaving the attached one alone."""
    items, users, queries = workload
    cfg = _cfg("sketch")
    art = IndexArtifact.build(items, users, _BUILD_KEY, config=cfg)
    eng_a = RkMIPSEngine.from_artifact(art)
    eng_b = RkMIPSEngine(cfg).build(items, users, _BUILD_KEY)
    ra = eng_a.query_batch(queries, 3)
    rb = eng_b.query_batch(queries, 3)
    np.testing.assert_array_equal(np.asarray(ra.predictions),
                                  np.asarray(rb.predictions))
    idx = sah.build(items, users, _BUILD_KEY, **cfg.build_kwargs())
    pred, _ = sah.rkmips_batch(idx, queries, 3, **cfg.query_kwargs())
    po = sah.predictions_to_original(idx, pred, users.shape[0])
    np.testing.assert_array_equal(np.asarray(ra.predictions), np.asarray(po))
    # engine.build attaches an artifact of its own
    assert eng_b.artifact is not None
    assert eng_b.artifact.fingerprint == art.fingerprint
    # value semantics: the mutation produces a new version, new fingerprint
    a2 = art.insert_items(jnp.ones((1, D)))
    assert a2 is not art and a2.fingerprint != art.fingerprint
    assert not art.has_pending and a2.has_pending
    np.testing.assert_array_equal(
        np.asarray(eng_a.query_batch(queries, 3).predictions),
        np.asarray(ra.predictions))


def test_build_input_validation(workload):
    """Dimensionality/dtype mistakes fail up front with clear ValueErrors,
    not as shape errors deep inside sah.build."""
    items, users, _ = workload
    eng = RkMIPSEngine(_cfg("sketch"))
    with pytest.raises(ValueError, match=r"items must be a 2-D \(n, d\)"):
        eng.build(items[0], users, _BUILD_KEY)
    with pytest.raises(ValueError, match=r"items must have a floating "
                                         r"dtype, got int32"):
        eng.build(jnp.ones((8, D), jnp.int32), users, _BUILD_KEY)
    with pytest.raises(ValueError, match=r"users must be a 2-D \(m, d\) "
                                         r"array or None"):
        eng.build(items, users[0], _BUILD_KEY)
    with pytest.raises(ValueError, match=r"users dimensionality \(8\) != "
                                         r"items dimensionality \(16\)"):
        eng.build(items, users[:, :8], _BUILD_KEY)
    with pytest.raises(ValueError, match=r"users must have a floating"):
        eng.build(items, jnp.ones((4, D), jnp.int32), _BUILD_KEY)
    with pytest.raises(ValueError, match=r"non-empty"):
        eng.build(items[:0], users, _BUILD_KEY)


def test_roundtrip_fingerprint_and_manifest(workload, tmp_path):
    """save/load round-trips bitwise (predictions AND counters), preserves
    the fingerprint, and refuses corrupted content."""
    items, users, queries = workload
    cfg = _cfg("sketch")
    art = IndexArtifact.build(items, users, _BUILD_KEY, config=cfg)
    art.ensure_kmips_index()                      # persist the kMIPS side too
    path = art.save(str(tmp_path / "art"))
    assert os.path.exists(os.path.join(path, "manifest.json"))
    art2 = load_artifact(str(tmp_path / "art"))
    assert art2.fingerprint == art.fingerprint
    assert art2.config == cfg
    assert art2.kmips_index is not None
    np.testing.assert_array_equal(np.asarray(art2.kmips_index.codes),
                                  np.asarray(art.kmips_index.codes))
    r1 = RkMIPSEngine.from_artifact(art).query_batch(queries, 3)
    r2 = RkMIPSEngine.from_artifact(art2).query_batch(queries, 3)
    np.testing.assert_array_equal(np.asarray(r1.predictions),
                                  np.asarray(r2.predictions))
    for f in _LOGICAL:
        np.testing.assert_array_equal(np.asarray(getattr(r1.stats, f)),
                                      np.asarray(getattr(r2.stats, f)))
    # staged deltas survive persistence
    a_mut, _ = _mutate(art, items, jax.random.PRNGKey(5))
    a_mut.save(str(tmp_path / "mut"))
    a_back = IndexArtifact.load(str(tmp_path / "mut"))
    assert a_back.has_pending and a_back.fingerprint == a_mut.fingerprint
    rm1 = RkMIPSEngine.from_artifact(a_mut).query_batch(queries, 3)
    rm2 = RkMIPSEngine.from_artifact(a_back).query_batch(queries, 3)
    np.testing.assert_array_equal(np.asarray(rm1.predictions),
                                  np.asarray(rm2.predictions))
    # integrity: a tampered manifest fingerprint refuses to load
    import json
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["metadata"]["fingerprint"] = "0" * 64
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError, match=r"fingerprint mismatch"):
        IndexArtifact.load(str(tmp_path / "art"))
    with pytest.raises(FileNotFoundError, match=r"no saved index artifact"):
        IndexArtifact.load(str(tmp_path / "nothing-here"))


def test_save_retention_never_deletes_live(workload, tmp_path):
    """save(dir, step=, keep=) prunes old versions, but the just-saved
    version always survives — even when its step number is the lowest in
    the directory — and keep < 1 is rejected before anything is written."""
    items, users, _ = workload
    art = IndexArtifact.build(items, users, _BUILD_KEY, config=_cfg("sketch"))
    adir = str(tmp_path / "vers")

    def steps():
        return sorted(int(n[5:]) for n in os.listdir(adir)
                      if n.startswith("step_"))

    for s in (1, 2, 3, 4):
        art.save(adir, step=s)
    art.save(adir, step=5, keep=2)
    assert steps() == [4, 5]
    # saving a LOWER step under a one-slot budget: the budget retains the
    # newest step (5), and protection keeps the version just written (1)
    art.save(adir, step=1, keep=1)
    assert steps() == [1, 5]
    back = IndexArtifact.load(adir, step=1)
    assert back.fingerprint == art.fingerprint
    with pytest.raises(ValueError, match=r"keep must be >= 1"):
        art.save(adir, step=9, keep=0)
    assert 9 not in steps()


def test_delta_exact_equivalence_precompact(workload):
    """THE streaming contract (hypothesis-free mirror): for exact-scan
    configs, insert_items/delete_items followed by queries are bitwise a
    from-scratch build on the mutated corpus — before any compact()."""
    items, users, queries = workload
    cfg = _cfg("exact")
    art = IndexArtifact.build(items, users, _BUILD_KEY, config=cfg)
    a, mutated = _mutate(art, items, jax.random.PRNGKey(5))
    np.testing.assert_array_equal(np.asarray(a.effective_items()),
                                  np.asarray(mutated))
    eng = RkMIPSEngine.from_artifact(a)
    ref = RkMIPSEngine(cfg).build(mutated, users, _BUILD_KEY)
    for k in (1, 3, 8):
        rd = eng.query_batch(queries, k)
        rr = ref.query_batch(queries, k)
        np.testing.assert_array_equal(np.asarray(rd.predictions),
                                      np.asarray(rr.predictions), err_msg=f"k={k}")
        # the exact config also equals the oracle on the mutated corpus
        np.testing.assert_array_equal(np.asarray(rd.predictions),
                                      np.asarray(eng.oracle(queries, k)))
    # single-query path agrees with its batch row
    s = eng.query(queries[0], 3)
    np.testing.assert_array_equal(np.asarray(s.predictions),
                                  np.asarray(eng.query_batch(queries, 3)
                                             .predictions[0]))


@pytest.mark.parametrize("scan", ["sketch", "exact"])
def test_compact_bitwise_from_scratch(workload, scan):
    """compact() == a cold build on the mutated corpus, bitwise, for every
    config (predictions and the layout-independent counters)."""
    items, users, queries = workload
    cfg = _cfg(scan)
    art = IndexArtifact.build(items, users, _BUILD_KEY, config=cfg)
    a, mutated = _mutate(art, items, jax.random.PRNGKey(9))
    ac = a.compact()
    assert not ac.has_pending and ac.delta_used == 0
    assert ac.n_base == a.n_items
    rc = RkMIPSEngine.from_artifact(ac).query_batch(queries, 3)
    rr = RkMIPSEngine(cfg).build(mutated, users, _BUILD_KEY).query_batch(
        queries, 3)
    np.testing.assert_array_equal(np.asarray(rc.predictions),
                                  np.asarray(rr.predictions))
    for f in _LOGICAL:
        np.testing.assert_array_equal(np.asarray(getattr(rc.stats, f)),
                                      np.asarray(getattr(rr.stats, f)))
    # nothing staged -> compact is the identity
    assert ac.compact() is ac


def test_delta_sketch_batched_equals_reference(workload):
    """For sketch configs the delta pipeline keeps the SS9 discipline: the
    batched dispatch is bitwise the per-query reference driver run on the
    same delta view (and the mapped legacy driver agrees too)."""
    items, users, queries = workload
    cfg = _cfg("sketch")
    art = IndexArtifact.build(items, users, _BUILD_KEY, config=cfg)
    a, _ = _mutate(art, items, jax.random.PRNGKey(11))
    eng = RkMIPSEngine.from_artifact(a)
    rb = eng.query_batch(queries, 3)
    view, d_i, d_m = a.query_view()
    assert d_i is not None
    pp = jnp.stack([sah.rkmips(view, q, 3, n_cand=cfg.n_cand, scan="sketch",
                               chunk=cfg.chunk, tie_eps=cfg.tie_eps,
                               delta_items=d_i, delta_mask=d_m)[0]
                    for q in queries])
    po = sah.predictions_to_original(view, pp, users.shape[0])
    np.testing.assert_array_equal(np.asarray(rb.predictions), np.asarray(po))
    rm = eng.query_batch_mapped(queries, 3)
    np.testing.assert_array_equal(np.asarray(rm.predictions),
                                  np.asarray(rb.predictions))


def test_delta_buffer_bookkeeping(workload):
    """Capacity is append-only until compact; ids are stable; misuse raises
    actionable errors."""
    items, users, _ = workload
    art = IndexArtifact.build(items, users, _BUILD_KEY, config=_cfg("exact"))
    n = items.shape[0]
    assert art.delta_capacity == 8 and art.n_items == n
    a = art.insert_items(jnp.ones((5, D)))
    assert a.delta_used == 5 and a.n_items == n + 5
    a = a.delete_items([n + 4])                    # retire a staged row
    assert a.n_items == n + 4 and a.delta_used == 5
    a = a.insert_items(jnp.ones((3, D)))           # slots are append-only
    assert a.delta_used == 8 and a.n_items == n + 7
    with pytest.raises(ValueError, match=r"delta buffer full: 1 rows do "
                                         r"not fit in the 0 free of 8"):
        a.insert_items(jnp.ones((1, D)))
    with pytest.raises(ValueError, match=r"item ids must be in \[0, 128\)"):
        a.delete_items([n + 8])
    with pytest.raises(ValueError, match=r"rows must be \(r, 16\)"):
        a.insert_items(jnp.ones((2, D + 1)))
    with pytest.raises(ValueError, match=r"rows must have a floating"):
        a.insert_items(jnp.ones((1, D), jnp.int32))
    # deleting the same id twice is idempotent
    b = art.delete_items([3]).delete_items([3])
    assert b.n_items == n - 1
    # compact resets the buffer and re-keys ids compactly
    c = a.compact()
    assert c.delta_used == 0 and c.n_base == n + 7
    # a (d,) row promotes to (1, d)
    assert c.insert_items(jnp.ones(D)).delta_used == 1


def test_kmips_reflects_deltas(workload):
    """Forward kMIPS over a delta-carrying artifact: deleted rows leave
    the scan, staged rows merge in exactly (ids n_base + slot), matching
    the exact oracle on the effective corpus at full re-rank depth."""
    items, users, queries = workload
    cfg = _cfg("exact")
    art = IndexArtifact.build(items, None, _BUILD_KEY, config=cfg)
    a, mutated = _mutate(art, items, jax.random.PRNGKey(13))
    eng = RkMIPSEngine.from_artifact(a)
    res = eng.kmips(queries, 4, n_cand=items.shape[0])
    vals, eids = exact.kmips(mutated, queries, 4)
    np.testing.assert_allclose(np.asarray(res.values), np.asarray(vals),
                               rtol=1e-6)
    # ids are the exact oracle's, translated into artifact id space
    # (surviving base rows keep their original ids; staged row j is
    # n_base + j) — element-wise, so deleted rows can never appear and a
    # winning staged row must surface from the merge
    n0 = items.shape[0]
    top_id = int(jnp.argmax(jnp.linalg.norm(items, axis=-1)))
    keep = np.setdiff1d(np.arange(n0), sorted({0, 7, 55, top_id}))
    live_slots = np.where(np.asarray(a.delta_mask))[0]
    eff_to_art = np.concatenate([keep, n0 + live_slots])
    np.testing.assert_array_equal(np.asarray(res.ids),
                                  eff_to_art[np.asarray(eids)])


def test_kmips_only_artifact_deltas(workload):
    """A kMIPS-only artifact (users=None) carries deltas too: attach wires
    the buffer even without a user-side index, so forward answers reflect
    staged rows and deletions (regression: the merge must not silently
    drop the buffer on the users=None attach path)."""
    items, _, queries = workload
    cfg = _cfg("exact")
    art = IndexArtifact.build(items, None, _BUILD_KEY, config=cfg)
    a = art.insert_items(items[:2] * 1.5).delete_items([0])
    res = RkMIPSEngine.from_artifact(a).kmips(queries, 5,
                                              n_cand=items.shape[0])
    vals, _ = exact.kmips(a.effective_items(), queries, 5)
    np.testing.assert_allclose(np.asarray(res.values), np.asarray(vals),
                               rtol=1e-6)
    # the boosted staged copies dominate their originals: staged ids
    # (n_base + slot) must actually surface from the merge
    assert (np.asarray(res.ids) >= items.shape[0]).any()
    with pytest.raises(RuntimeError, match=r"not built for RkMIPS"):
        RkMIPSEngine.from_artifact(a).query(queries[0], 3)


def test_churn_never_retraces(workload):
    """One executable for the plain pipeline, at most one more for the
    delta pipeline — ever: inserts, deletions, swaps and compact reuse
    them as long as shapes are unchanged."""
    items, users, queries = workload
    cfg = _cfg("sketch")
    art = IndexArtifact.build(items, users, _BUILD_KEY, config=cfg)
    eng = RkMIPSEngine.from_artifact(art)
    eng.query_batch(queries, 3)
    assert eng.rkmips_compile_count == 1
    eng.attach(art.delete_items([1, 2]))          # delete-only: plain path
    eng.query_batch(queries, 3)
    assert eng.rkmips_compile_count == 1
    a = art.insert_items(jnp.ones((2, D)))
    eng.attach(a)                                  # the one extra compile
    eng.query_batch(queries, 3)
    assert eng.rkmips_compile_count == 2
    eng.attach(a.insert_items(jnp.ones((3, D))).delete_items([9]))
    eng.query_batch(queries, 3)
    assert eng.rkmips_compile_count == 2
    compacted = a.compact()                        # 122 rows: same padded
    eng.attach(compacted)                          # shapes as the base
    eng.query_batch(queries, 3)
    assert eng.rkmips_compile_count == 2


def test_server_swap_keeps_tickets_and_executables(workload):
    """Hot swap on both servers: pending tickets are answered against the
    new version, in order, with zero new compiles for same-shape versions;
    the forward cache keeps old versions warm under their fingerprints."""
    items, users, queries = workload
    cfg = _cfg("sketch")
    k2 = jax.random.PRNGKey(41)
    items_v2 = items + 0.01 * jax.random.normal(k2, items.shape)
    art = IndexArtifact.build(items, users, _BUILD_KEY, config=cfg)
    art2 = IndexArtifact.build(items_v2, users, _BUILD_KEY, config=cfg)

    eng = RkMIPSEngine.from_artifact(art)
    rsrv = eng.reverse_server()
    rsrv.submit(queries[:2])
    rsrv.flush(3)
    c0 = rsrv.compile_count
    rsrv.submit(queries)                           # 4 pending tickets
    rsrv.swap(art2)
    assert rsrv.pending == 4
    res = rsrv.flush(3)
    assert rsrv.compile_count == c0                # zero new executables
    ref = RkMIPSEngine.from_artifact(art2).query_batch(queries, 3)
    for i, r in enumerate(res):
        np.testing.assert_array_equal(np.asarray(r.predictions),
                                      np.asarray(ref.predictions[i]))
    # swapping in a kMIPS-only artifact is refused BEFORE touching the
    # engine: pending tickets stay servable afterwards
    rsrv.submit(queries[:2])
    with pytest.raises(RuntimeError, match=r"not built for RkMIPS"):
        rsrv.swap(IndexArtifact.build(items, None, _BUILD_KEY, config=cfg))
    assert rsrv.pending == 2 and eng.artifact is art2
    refused = rsrv.flush(3)
    assert len(refused) == 2
    np.testing.assert_array_equal(np.asarray(refused[0].predictions),
                                  np.asarray(ref.predictions[0]))

    fsrv = RetrievalServer.from_artifact(art)
    assert fsrv.cache.builds == 0                  # seeded when available
    fsrv.submit(queries[:3])
    fsrv.flush(3)
    cc, b0 = fsrv.compile_count, fsrv.cache.builds
    fsrv.submit(queries[:2])
    fsrv.swap(art2)
    assert fsrv.pending == 2
    out = fsrv.flush(3)
    assert len(out) == 2
    assert fsrv.compile_count == cc                # same (batch, k) shapes
    assert fsrv.cache.builds == b0 + 1             # v2 built once
    assert fsrv.cache.fingerprint == art2.base_fingerprint
    fsrv.swap(art)                                 # swap back: still cached
    fsrv.submit(queries[0])
    fsrv.flush(3)
    assert fsrv.cache.builds == b0 + 1


def test_attach_guards(workload):
    items, users, queries = workload
    cfg = _cfg("sketch")
    art = IndexArtifact.build(items, users, _BUILD_KEY, config=cfg)
    with pytest.raises(TypeError, match=r"attach expects an IndexArtifact"):
        RkMIPSEngine(cfg).attach("not-an-artifact")
    other = RkMIPSEngine(cfg.replace(n_bits=64))
    with pytest.raises(ValueError, match=r"artifact config does not match"):
        other.attach(art)
    # delta_capacity is a lifecycle knob, not a recipe field: configs
    # differing only there are interchangeable (engine/config.py contract)
    wider = RkMIPSEngine(cfg.replace(delta_capacity=64)).attach(art)
    np.testing.assert_array_equal(
        np.asarray(wider.query_batch(queries, 3).predictions),
        np.asarray(RkMIPSEngine.from_artifact(art)
                   .query_batch(queries, 3).predictions))
    km_only = IndexArtifact.build(items, None, _BUILD_KEY, config=cfg)
    with pytest.raises(RuntimeError, match=r"no user-side index"):
        km_only.query_view()


def test_server_ids_agree_with_engine_kmips(workload):
    """The two forward surfaces of one delta-carrying artifact answer in
    the same id space: a hot-swapped RetrievalServer's ids are artifact
    ids (base ids preserved across deletions; staged row j = n_base + j),
    matching engine.kmips id-for-id."""
    items, _, queries = workload
    cfg = _cfg("exact")
    art = IndexArtifact.build(items, None, _BUILD_KEY, config=cfg)
    a, _ = _mutate(art, items, jax.random.PRNGKey(17))
    eng = RkMIPSEngine.from_artifact(a)
    srv = RetrievalServer.from_artifact(a)
    srv.submit(queries)
    served = srv.flush(4, n_cand=items.shape[0])
    ref = eng.kmips(queries, 4, n_cand=items.shape[0])
    for i, r in enumerate(served):
        np.testing.assert_array_equal(np.asarray(r.ids),
                                      np.asarray(ref.ids[i]))
        np.testing.assert_allclose(np.asarray(r.values),
                                   np.asarray(ref.values[i]), rtol=1e-6)
    # swap() adopts the new config's cache capacity along with the rest
    art_cap = IndexArtifact.build(
        items, None, _BUILD_KEY,
        config=cfg.replace(serve_cache_capacity=7))
    srv.swap(art_cap)
    assert srv.cache.capacity == 7


_ELASTIC_SCRIPT = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.engine import IndexArtifact, RkMIPSEngine, get_config
from repro.dist.policy import ShardingPolicy
from repro.data import synthetic

key = jax.random.PRNGKey(0)
ki, kq, kb, kd = jax.random.split(key, 4)
items, users = synthetic.recommendation_data(ki, 509, 1013, 32)  # primes
queries = synthetic.queries_from_items(kq, items, 3)
cfg = get_config("sah").replace(tile=128, n_bits=64, delta_capacity=16)

art = IndexArtifact.build(items, users, kb, config=cfg)
mesh8 = jax.make_mesh((2, 4), ("data", "model"))
eng8 = RkMIPSEngine.from_artifact(art, policy=ShardingPolicy(mesh=mesh8,
                                                             rules={}))
r8 = eng8.query_batch(queries, 10)

with tempfile.TemporaryDirectory() as d:
    art.save(d)                                   # host-gathered, any mesh
    art2 = IndexArtifact.load(d)
assert art2.fingerprint == art.fingerprint

mesh4 = jax.sharding.Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2),
                          ("data", "model"))
eng4 = RkMIPSEngine.from_artifact(art2, policy=ShardingPolicy(mesh=mesh4,
                                                              rules={}))
eng1 = RkMIPSEngine.from_artifact(art2)
r4 = eng4.query_batch(queries, 10)
r1 = eng1.query_batch(queries, 10)
for r in (r4, r1):
    np.testing.assert_array_equal(np.asarray(r8.predictions),
                                  np.asarray(r.predictions))
    for f in ("blocks_alive", "users_alive", "n_no_lb", "n_yes_norm",
              "n_scan"):
        np.testing.assert_array_equal(np.asarray(getattr(r8.stats, f)),
                                      np.asarray(getattr(r.stats, f)))
print("elastic roundtrip OK")

# Staged deltas shard too: delta counts are shard-local, psum'd counters
# and gathered predictions bitwise equal the single-device delta path.
rows = jax.random.normal(kd, (7, 32))
a = art.insert_items(rows).delete_items([2, 100, 509 + 1])
eng8.attach(a); eng1.attach(a)
d8 = eng8.query_batch(queries, 10)
d1 = eng1.query_batch(queries, 10)
np.testing.assert_array_equal(np.asarray(d8.predictions),
                              np.asarray(d1.predictions))
for f in ("blocks_alive", "users_alive", "n_no_lb", "n_yes_norm", "n_scan"):
    np.testing.assert_array_equal(np.asarray(getattr(d8.stats, f)),
                                  np.asarray(getattr(d1.stats, f)))
print("sharded delta OK")

# swap on a mesh: same shapes, no new dispatch signatures
n0 = eng8.rkmips_compile_count
eng8.attach(a.insert_items(jax.random.normal(kq, (2, 32))))
eng8.query_batch(queries, 10)
assert eng8.rkmips_compile_count == n0, eng8.rkmips_compile_count
print("mesh swap zero-retrace OK")
print("ALL ARTIFACT ELASTIC OK")
"""


@pytest.mark.slow
def test_artifact_elastic_mesh_change():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _ELASTIC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL ARTIFACT ELASTIC OK" in out.stdout
    assert "elastic roundtrip OK" in out.stdout
    assert "sharded delta OK" in out.stdout
    assert "mesh swap zero-retrace OK" in out.stdout
