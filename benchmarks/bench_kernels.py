"""Kernel-level microbenchmarks: jnp reference path timings on CPU (the
Pallas kernels themselves target TPU; interpret-mode timing is meaningless,
so we time the dispatch path the CPU benchmarks actually use, plus report
the bytes-reduction each kernel achieves on TPU by construction).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.kernels import ops


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(n=65536, d=128, n_bits=256, q=64):
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (n, d))
    proj = jax.random.normal(k2, (d, n_bits))
    queries = jax.random.normal(k3, (q, d))

    rows = []
    dt = _time(ops.srp_hash, x, proj)
    rows.append(common.fmt_row(
        "kernel/srp_hash", dt * 1e6,
        f"n={n};bits={n_bits};tpu_hbm_out_bytes=1/{8 * 4}x_of_signs"))

    codes = ops.srp_hash(x, proj)
    qcodes = ops.srp_hash(queries, proj)
    dt = _time(ops.hamming_scores, qcodes, codes)
    ip_bytes = n * d * 4
    code_bytes = n * (n_bits // 8)
    rows.append(common.fmt_row(
        "kernel/hamming_scores", dt * 1e6,
        f"q={q};n={n};bytes_vs_exact={code_bytes / ip_bytes:.3f}"))

    dt = _time(lambda a, b: ops.ip_topk(a, b, 100), queries, x)
    rows.append(common.fmt_row("kernel/ip_topk", dt * 1e6, f"k=100;n={n}"))
    return rows
