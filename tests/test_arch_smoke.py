"""Per-architecture smoke tests: REDUCED config of each assigned arch, one
forward/train step on CPU, asserting output shapes + finite values."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfg_base
from repro.data import graph as graph_data
from repro.launch import cells as cells_lib
from repro.models import gat as gat_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tf_lib
from repro.train import optimizer as opt_lib
from repro.train.trainer import TrainState, make_train_step

LM_ARCHS = ["dbrx-132b", "olmoe-1b-7b", "qwen3-0.6b", "qwen2-1.5b",
            "mistral-nemo-12b"]


def _finite(tree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.mark.parametrize("arch_id", LM_ARCHS)
def test_lm_smoke(arch_id):
    arch = cfg_base.get(arch_id)
    cfg = arch.make_smoke_config()
    key = jax.random.PRNGKey(0)
    params = tf_lib.init_params(key, cfg)
    tokens = jax.random.randint(key, (2, 32), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}

    hidden, aux, _ = tf_lib.forward(params, tokens, cfg)
    assert hidden.shape == (2, 32, cfg.d_model)
    logits = tf_lib.full_logits(params, hidden, cfg)
    assert logits.shape == (2, 32, cfg.vocab)
    assert _finite({"h": hidden, "l": logits})

    opt = opt_lib.adamw(1e-3)
    step = make_train_step(
        lambda p, b: tf_lib.lm_loss(p, b, cfg), opt)
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert _finite(state.params)

    # decode step
    _, cache = tf_lib.prefill(params, tokens[:, :16], cfg)
    lg, cache = tf_lib.decode_step(params, cache, tokens[:, 16], cfg)
    assert lg.shape == (2, cfg.vocab)
    assert int(cache["length"]) == 17
    assert _finite({"lg": lg})


def test_gat_smoke():
    arch = cfg_base.get("gat-cora")
    cfg = arch.make_smoke_config()
    rng = np.random.default_rng(0)
    g = graph_data.random_power_law_graph(rng, 64, 4, cfg.d_in,
                                          cfg.n_classes)
    sub = graph_data.sample_subgraph(rng, g, np.arange(8), (4, 3),
                                     pad_nodes=64, pad_edges=128)
    batch = {k: jnp.asarray(v) for k, v in sub.items()}
    params = gat_lib.init_params(jax.random.PRNGKey(0), cfg)
    logits = gat_lib.forward(params, batch, cfg)
    assert logits.shape == (64, cfg.n_classes)
    opt = opt_lib.adamw(1e-2)
    step = make_train_step(lambda p, b: gat_lib.loss_fn(p, b, cfg), opt)
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_gat_molecule_smoke():
    arch = cfg_base.get("gat-cora")
    cfg = arch.make_smoke_config()
    rng = np.random.default_rng(1)
    batch = {k: jnp.asarray(v) for k, v in graph_data.molecule_batch(
        rng, 8, 6, 10, cfg.d_in, cfg.n_classes, pad_edges=128).items()}
    params = gat_lib.init_params(jax.random.PRNGKey(0), cfg)
    loss = gat_lib.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch_id", ["deepfm", "xdeepfm"])
def test_ctr_smoke(arch_id):
    arch = cfg_base.get(arch_id)
    cfg = arch.make_smoke_config()
    key = jax.random.PRNGKey(0)
    params = rec_lib.init_ctr_params(key, cfg)
    b = 16
    batch = {
        "sparse": jnp.stack(
            [jax.random.randint(jax.random.fold_in(key, i), (b,), 0, v)
             for i, v in enumerate(cfg.embedding.vocab_sizes)], axis=-1),
        "label": jax.random.bernoulli(key, 0.3, (b,)).astype(jnp.float32),
    }
    logits = rec_lib.ctr_forward(params, batch, cfg)
    assert logits.shape == (b,)
    opt = opt_lib.adamw(1e-3)
    step = make_train_step(lambda p, bt: rec_lib.ctr_loss(p, bt, cfg), opt)
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
    state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_din_smoke():
    arch = cfg_base.get("din")
    cfg = arch.make_smoke_config()
    key = jax.random.PRNGKey(0)
    params = rec_lib.init_din_params(key, cfg)
    b, t = 8, cfg.seq_len
    vs = cfg.embedding.vocab_sizes
    batch = {
        "hist": jax.random.randint(key, (b, t), 0, vs[0]),
        "hist_mask": jnp.ones((b, t), bool),
        "target": jax.random.randint(key, (b,), 0, vs[0]),
        "profile": jnp.stack(
            [jax.random.randint(jax.random.fold_in(key, i), (b,), 0, v)
             for i, v in enumerate(vs[1:])], axis=-1),
        "label": jax.random.bernoulli(key, 0.5, (b,)).astype(jnp.float32),
    }
    logits = rec_lib.din_forward(params, batch, cfg)
    assert logits.shape == (b,)
    assert np.isfinite(float(rec_lib.din_loss(params, batch, cfg)))


def test_twotower_smoke():
    arch = cfg_base.get("two-tower-retrieval")
    cfg = arch.make_smoke_config()
    key = jax.random.PRNGKey(0)
    params = rec_lib.init_twotower_params(key, cfg)
    b = 8
    batch = {
        "user_feats": jnp.stack(
            [jax.random.randint(jax.random.fold_in(key, i), (b,), 0, v)
             for i, v in enumerate(cfg.user_embedding.vocab_sizes)], -1),
        "item_feats": jnp.stack(
            [jax.random.randint(jax.random.fold_in(key, 9 + i), (b,), 0, v)
             for i, v in enumerate(cfg.item_embedding.vocab_sizes)], -1),
        "log_q": jnp.zeros((b,)),
    }
    u = rec_lib.user_tower(params, batch["user_feats"], cfg)
    v = rec_lib.item_tower(params, batch["item_feats"], cfg)
    assert u.shape == (b, cfg.out_dim) and v.shape == (b, cfg.out_dim)
    assert np.isfinite(float(rec_lib.twotower_loss(params, batch, cfg)))


def test_all_archs_registered():
    assert len(cfg_base.all_archs()) == 10
    for arch_id in cfg_base.all_archs():
        arch = cfg_base.get(arch_id)
        assert arch.shapes, arch_id
        assert callable(arch.make_config)
        # full configs instantiate as metadata (no arrays)
        cfg = arch.make_config()
        assert cfg is not None


def test_cells_build_without_mesh():
    """Every (arch x shape) cell builds abstract args on CPU (mesh=None)."""
    for arch_id in cfg_base.all_archs():
        arch = cfg_base.get(arch_id)
        for shape in arch.shapes:
            cell = cells_lib.build_cell(arch_id, shape.name, None)
            assert cell.abstract_args is not None, (arch_id, shape.name)
