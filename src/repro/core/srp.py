"""Sign Random Projection (SimHash) sketches, bit-packed for Hamming scanning.

The paper uses K SimHash tables with bucket probing. On TPU we keep the same
hash family (SRP, Eq. 1) but replace bucket indirection with a bit-packed code
+ Hamming-distance ranking: for B independent SRP bits,

    E[hamming(code(p), code(u))] = B * theta(p, u) / pi        (from Eq. 2)

so ranking items by Hamming distance to the query code is an unbiased ranking
by estimated angular distance -- exactly the quantity SA-ALSH's NNS needs.
Candidates are then re-ranked with exact inner products.

Codes are packed 32 bits / uint32 lane; all shapes padded to multiples of 32.
The heavy scan (XOR + popcount over (users x items x words)) has a Pallas
kernel in repro.kernels.hamming_topk; this module holds the jnp reference path
used on CPU and for index building.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_BITS_PER_WORD = 32
# Powers of two for packing: bit j of a word is set iff sign bit j is positive.
_POW2 = (2 ** jnp.arange(_BITS_PER_WORD, dtype=jnp.uint32)).astype(jnp.uint32)


def make_projection(key: jax.Array, dim: int, n_bits: int) -> jnp.ndarray:
    """Gaussian projection matrix A (dim, n_bits), entries ~ N(0, 1)."""
    if n_bits % _BITS_PER_WORD != 0:
        raise ValueError(f"n_bits must be a multiple of 32, got {n_bits}")
    return jax.random.normal(key, (dim, n_bits), dtype=jnp.float32)


def pack_signs(signs: jnp.ndarray) -> jnp.ndarray:
    """Pack a boolean sign matrix (n, B) into uint32 codes (n, B//32)."""
    n, b = signs.shape
    w = b // _BITS_PER_WORD
    grouped = signs.reshape(n, w, _BITS_PER_WORD).astype(jnp.uint32)
    return jnp.sum(grouped * _POW2[None, None, :], axis=-1, dtype=jnp.uint32)


def srp_codes(x: jnp.ndarray, proj: jnp.ndarray) -> jnp.ndarray:
    """SRP codes of rows of x (n, dim) under proj (dim, B) -> uint32 (n, B//32)."""
    return pack_signs(x @ proj >= 0.0)


def hamming_distance(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """All-pairs Hamming distance between packed codes.

    a (na, W) uint32, b (nb, W) uint32 -> (na, nb) int32.
    """
    x = jnp.bitwise_xor(a[:, None, :], b[None, :, :])
    return jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.int32)
