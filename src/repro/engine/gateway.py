"""Multi-tenant serving gateway: N tenants, one worker pool, one trace
cache (DESIGN.md SS15).

``engine/runtime.py`` gives the repo ONE threaded serving loop per index.
This module is the tier above it: a ``ServingGateway`` hosts many tenants,
each binding a name to an ``IndexArtifact`` version (forward and/or
reverse) plus a ``TenantPolicy`` — admission limits (max k, max in-flight
tickets), a per-ticket scan budget, a default deadline. ``submit(tenant,
q)`` routes by tenant name to the artifact *fingerprint* registered for
it, admission-validates against the policy, and dispatches through the
tenant's own ``ServingRuntime``.

What makes it a tier rather than a dict of runtimes:

  * **One worker pool.** Every tenant runtime is constructed with
    ``pool=`` (``runtime.WorkerPool``): a fixed set of threads round-robins
    across tenants with non-blocking dispatch-lock acquisition, so one
    tenant's hot-swap / compaction / slow flush never stalls another
    tenant's traffic (the pool docstring is the non-stall contract).
  * **One compiled-trace cache.** Tenants whose configs agree in every
    field except ``scan_budget`` (an execution-only knob threaded as a
    traced operand, never a static) adopt the first such tenant's
    dispatch via ``share_dispatch`` — engine-level for reverse tenants,
    server-level for forward ones. Two tenants with identical
    (rung, k, n_cand, scan) signatures therefore share one executable,
    and ``warmup()`` is gateway-wide: it warms one representative per
    share group and re-baselines every member, so
    ``stats().traces_after_warmup == 0`` holds across ALL tenants after
    one warmup pass (pinned by tests/test_gateway.py).
  * **Budgets that are visible, never silent.** A tenant's
    ``scan_budget`` caps how many index tiles the reverse execute scan
    may visit per query (core/sah.py): lanes of a budget-exhausted query
    resolve conservatively ("not in the audience"), the ticket comes
    back ``truncated=True`` with the batch's pruning-funnel snapshot,
    and ``RuntimeStats.truncated`` attributes the count per tenant.
  * **Per-tenant lifecycle.** ``swap`` / ``insert_items`` /
    ``delete_items`` / ``request_compaction`` address one tenant and ride
    that tenant's own locks; routing fingerprints follow the live
    version.

Answers are bitwise identical to a dedicated per-tenant runtime: the
gateway adds admission and routing, never a private dispatch path.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

from repro.dist.policy import NO_SHARDING, ShardingPolicy
from repro.engine import runtime as _runtime
from repro.engine import serving as _serving
from repro.engine.artifact import IndexArtifact
from repro.engine.engine import RkMIPSEngine


@dataclasses.dataclass(frozen=True)
class TenantPolicy:
    """Admission + execution limits for one gateway tenant.

    max_k          largest ``k`` a ticket may ask for (None: the artifact
                   config's own ``k_max`` is the only cap).
    max_in_flight  admission cap on unresolved tickets; a submit past it
                   is rejected up front (None: unbounded).
    scan_budget    per-query cap on reverse execute tile visits
                   (``EngineConfig.scan_budget``; 0 = uncapped). An
                   execution-only knob: it never enters artifact
                   fingerprints and budgeted tenants share unbudgeted
                   tenants' executables (the budget is a traced operand).
    deadline       default per-ticket wall-clock budget in seconds
                   (None: no deadline); ``submit(deadline=)`` overrides.
    """

    max_k: int | None = None
    max_in_flight: int | None = None
    scan_budget: int = 0
    deadline: float | None = None

    def __post_init__(self):
        if self.max_k is not None and self.max_k < 1:
            raise ValueError(f"max_k must be >= 1, got {self.max_k}")
        if self.max_in_flight is not None and self.max_in_flight < 1:
            raise ValueError(f"max_in_flight must be >= 1, got "
                             f"{self.max_in_flight}")
        if self.scan_budget < 0:
            raise ValueError(f"scan_budget must be >= 0 (0 = uncapped), "
                             f"got {self.scan_budget}")


class GatewayStats(NamedTuple):
    """``ServingGateway.stats()`` snapshot.

    tenants:              per-tenant ``RuntimeStats`` — counters are
                          attributed to the tenant whose runtime did the
                          work, never pooled (stats isolation is pinned
                          by tests/test_gateway.py).
    traces_after_warmup:  gateway-wide traces since ``warmup()``, summed
                          over *distinct* share groups (a trace a shared
                          dispatch cost is counted once, not once per
                          sharer). 0 after a gateway-wide warmup until
                          something actually re-traces.
    """

    tenants: dict
    traces_after_warmup: int


class _Tenant(NamedTuple):
    runtime: object            # ServingRuntime
    policy: TenantPolicy
    mode: str                  # "forward" | "reverse"
    traces: object             # the share group's _TraceCount


class ServingGateway:
    """N tenants, one worker pool, one trace cache (module docstring).

    Parameters:
      pool_workers   dispatch threads shared by every tenant.
      poll_interval  pool idle wakeup (seconds); bounds pooled linger
                     latency.
    """

    def __init__(self, *, pool_workers: int = 1,
                 poll_interval: float = 0.01):
        self.pool = _runtime.WorkerPool(pool_workers,
                                        poll_interval=poll_interval)
        self._tenants: dict[str, _Tenant] = {}
        self._fingerprints: dict[str, str] = {}   # tenant -> live version
        self._group_base: dict[int, tuple[object, int]] = {}
        self._closed = False

    # -- registration ------------------------------------------------------

    def _share_donor(self, config, sharding: ShardingPolicy, mode: str):
        """The first registered tenant this one can adopt a dispatch
        from: same mode, same mesh, and (reverse) a config equal in every
        field except ``scan_budget``. Forward dispatch closures are
        config-free, so mesh identity alone suffices there."""
        for t in self._tenants.values():
            if t.mode != mode:
                continue
            if mode == "reverse":
                donor = t.runtime.server.engine
                if donor.policy.mesh is not sharding.mesh:
                    continue
                if donor.config.replace(scan_budget=config.scan_budget) \
                        != config:
                    continue
                return donor
            donor = t.runtime.server
            if donor.policy.mesh is not sharding.mesh:
                continue
            return donor
        return None

    def register(self, name: str, artifact: IndexArtifact, *,
                 policy: TenantPolicy | None = None, k: int | None = None,
                 sharding: ShardingPolicy = NO_SHARDING,
                 mode: str = "auto", **runtime_kwargs):
        """Bind ``name`` to an artifact version + policy; returns the
        tenant's ``ServingRuntime``.

        ``mode`` is "reverse" (RkMIPS, needs a user-side build),
        "forward" (kMIPS retrieval), or "auto" (reverse iff the artifact
        carries users). Extra keyword args go to ``ServingRuntime``
        (compaction, artifact_dir, batch_linger, ...). The runtime is
        pooled — never pass ``pool=``/``workers=`` here.
        """
        if self._closed:
            raise RuntimeError("gateway is closed: no new tenants")
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} is already registered; "
                             f"swap(name, artifact) replaces its version")
        policy = TenantPolicy() if policy is None else policy
        if mode == "auto":
            mode = "reverse" if artifact.users is not None else "forward"
        if mode not in ("forward", "reverse"):
            raise ValueError(f"mode must be 'auto', 'forward' or "
                             f"'reverse', got {mode!r}")
        if mode == "reverse" and artifact.users is None:
            raise ValueError(
                f"tenant {name!r}: mode='reverse' needs an artifact built "
                f"for RkMIPS (users=None in this one)")
        for bad in ("pool", "workers", "deadline"):
            if bad in runtime_kwargs:
                raise ValueError(f"register() manages {bad!r} itself: the "
                                 f"pool is gateway-wide and the deadline "
                                 f"comes from TenantPolicy")

        cfg = artifact.config.replace(scan_budget=policy.scan_budget)
        donor = self._share_donor(cfg, sharding, mode)
        if mode == "reverse":
            engine = RkMIPSEngine(cfg, policy=sharding,
                                  share_dispatch=donor).attach(artifact)
            server = _serving.ReverseServer(engine)
            traces = engine._traces
        else:
            if policy.scan_budget:
                raise ValueError(
                    f"tenant {name!r}: scan_budget is a reverse-pipeline "
                    f"knob (the forward scan has no execute loop to cap)")
            server = _serving.RetrievalServer.from_artifact(
                artifact, policy=sharding, share_dispatch=donor)
            traces = server._traces
        rt = _runtime.ServingRuntime(server, k=k, pool=self.pool,
                                     deadline=policy.deadline,
                                     **runtime_kwargs)
        self._tenants[name] = _Tenant(rt, policy, mode, traces)
        self._fingerprints[name] = artifact.fingerprint
        return rt

    # -- routing + admission -----------------------------------------------

    def _entry(self, tenant: str) -> _Tenant:
        try:
            return self._tenants[tenant]
        except KeyError:
            raise KeyError(
                f"unknown tenant {tenant!r}: registered tenants are "
                f"{sorted(self._tenants)}") from None

    def route(self, tenant: str) -> str:
        """The artifact fingerprint ``tenant`` currently routes to (the
        live version's content hash — follows swaps and churn)."""
        self._entry(tenant)
        return self._fingerprints[tenant]

    def submit(self, tenant: str, q, *, k: int | None = None, **kwargs):
        """Admit a query for ``tenant`` -> ``ServeTicket`` (one per row
        for a block). Routing is by registered name; admission validates
        against the tenant's ``TenantPolicy`` with explicit rejection
        messages (never a silent drop):

          * unknown tenant            -> KeyError naming the known ones
          * k above ``max_k``         -> ValueError naming both numbers
          * ``max_in_flight`` reached -> RuntimeError naming the cap

        Everything else (dtype/shape validation, deadlines, signature
        batching) is the tenant runtime's own ``submit``.
        """
        t = self._entry(tenant)
        ask = t.runtime._default_k if k is None else k
        if t.policy.max_k is not None and ask is not None \
                and ask > t.policy.max_k:
            raise ValueError(f"tenant {tenant!r}: k={ask} exceeds policy "
                             f"max_k={t.policy.max_k}")
        if t.policy.max_in_flight is not None \
                and t.runtime.pending >= t.policy.max_in_flight:
            raise RuntimeError(
                f"tenant {tenant!r}: {t.runtime.pending} tickets in "
                f"flight >= policy max_in_flight="
                f"{t.policy.max_in_flight}; resolve or drain first")
        return t.runtime.submit(q, k=k, **kwargs)

    # -- gateway-wide warmup + stats ---------------------------------------

    def warmup(self, ks=None) -> int:
        """Gateway-wide AOT warmup (DESIGN.md SS14/SS15): for each
        *share group* (tenants adopting one compiled dispatch), warm one
        representative at the union of the group's default ks (plus
        ``ks``), then re-baseline every tenant — warming N tenants that
        share a signature traces it once, and afterwards
        ``stats().traces_after_warmup == 0`` across all tenants. Returns
        the number of (bucket, k) cells compiled."""
        groups: dict[int, tuple[_Tenant, set]] = {}
        for t in self._tenants.values():
            rep, want = groups.setdefault(id(t.traces), (t, set()))
            if t.runtime._default_k is not None:
                want.add(t.runtime._default_k)
            if ks is not None:
                want.update(ks)
        cells = 0
        for rep, want in groups.values():
            if want:
                cells += rep.runtime.warmup(sorted(want))
        self._group_base = {
            gid: (rep.traces, rep.traces.n)
            for gid, (rep, _) in groups.items()}
        for t in self._tenants.values():
            t.runtime.rebaseline_traces()
        return cells

    def stats(self) -> GatewayStats:
        """Per-tenant ``RuntimeStats`` + gateway-wide traces since the
        last ``warmup()`` (summed over distinct share groups; before any
        warmup it counts every trace the gateway's tenants have cost)."""
        if self._group_base:
            traces = sum(tc.n - base
                         for tc, base in self._group_base.values())
        else:
            seen: dict[int, int] = {}
            for t in self._tenants.values():
                seen[id(t.traces)] = t.traces.n
            traces = sum(seen.values())
        return GatewayStats(
            tenants={name: t.runtime.stats
                     for name, t in self._tenants.items()},
            traces_after_warmup=traces)

    # -- per-tenant lifecycle ----------------------------------------------

    def runtime(self, tenant: str):
        """The tenant's ``ServingRuntime`` (escape hatch: drain one
        tenant, read ``last_compaction_seconds``, ...)."""
        return self._entry(tenant).runtime

    def swap(self, tenant: str, artifact: IndexArtifact) -> None:
        """Hot-swap ``tenant``'s live version (between that tenant's
        flushes — other tenants' dispatch never waits on it: the pool
        skips a locked tenant). Routing follows: ``route(tenant)`` is the
        new fingerprint."""
        t = self._entry(tenant)
        t.runtime.swap(artifact)
        self._fingerprints[tenant] = artifact.fingerprint

    def insert_items(self, tenant: str, rows) -> IndexArtifact:
        """Stage rows into ``tenant``'s delta buffer; returns (and
        routes to) the new version."""
        t = self._entry(tenant)
        art = t.runtime.insert_items(rows)
        self._fingerprints[tenant] = art.fingerprint
        return art

    def delete_items(self, tenant: str, ids) -> IndexArtifact:
        """Retire rows on ``tenant``'s live version; returns (and routes
        to) the new version."""
        t = self._entry(tenant)
        art = t.runtime.delete_items(ids)
        self._fingerprints[tenant] = art.fingerprint
        return art

    def request_compaction(self, tenant: str) -> None:
        """Ask ``tenant``'s maintenance thread for a compaction now
        (requires that tenant registered with ``compaction=True``)."""
        self._entry(tenant).runtime.request_compaction()

    # -- lifecycle ---------------------------------------------------------

    @property
    def tenants(self) -> tuple[str, ...]:
        return tuple(self._tenants)

    def drain(self, timeout: float | None = None) -> bool:
        """Block until every tenant's admitted tickets have resolved."""
        ok = True
        for t in self._tenants.values():
            ok = t.runtime.drain(timeout) and ok
        return ok

    def close(self, *, drain: bool = True,
              timeout: float | None = None) -> None:
        """Close every tenant runtime (optionally draining), then stop
        the shared pool. Idempotent."""
        if self._closed:
            return
        self._closed = True
        for t in self._tenants.values():
            t.runtime.close(drain=drain, timeout=timeout)
        self.pool.close()

    def __enter__(self) -> "ServingGateway":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))
