"""Engine-level online serving: micro-batched (R)kMIPS behind one front door.

DESIGN.md SS8 is the contract. This module is what ``launch/serve.py`` and
``examples/serve_retrieval.py`` sit on: single queries arrive one at a time,
are accumulated and padded into fixed-size micro-batches (static shapes —
exactly one compile per distinct batch size), and dispatched through the
mesh-aware sharded scan ``engine/sharding.py::kmips_flat_arrays``. Built
serving state — norm-ordered item rows, SRP codes, the query-side
projection, and their padded, mesh-placed layout — is cached in an LRU
keyed by the frozen ``EngineConfig``, so swapping presets on a live server
rebuilds nothing it has already built.

Forward (kMIPS) serving, three layers, separable on purpose:

  * ``build_serving_state`` — offline: SA-ALSH index build, row padding to
    the mesh's shard multiple (``pad_item_rows``), device placement.
  * ``ServingCache`` — the LRU of built states, keyed by (corpus
    fingerprint, index recipe); ``get`` is the only entry, ``builds``
    counts misses (asserted in tests).
  * ``RetrievalServer`` — online: ``submit`` enqueues a query and returns
    its ticket, ``flush`` answers every pending ticket in order; ``kmips``
    is the submit+flush convenience for a lone query.

Hot swap (DESIGN.md SS10): both servers accept a new ``IndexArtifact``
version between flushes via ``swap(artifact)`` — pending tickets survive
(they are answered against the new version by the next flush), and when the
swapped-in shapes match the live ones the compiled dispatch is reused
(``compile_count`` += 0). The cache key's fingerprint prefix is what makes
this safe: built states of *different* corpus versions can coexist in one
LRU, so swapping back to a cached version is a hit, and a stale state can
never be served as a "hit" for new content. For artifact-backed forward
servers the prefix is the **base** fingerprint and staged deltas are served
as an incremental overlay (deletion mask + exactly-scanned staged rows), so
streaming churn never rebuilds serving state — the cache key only moves at
``compact()``, when the base actually changes.

The synchronous path here is also the substrate of the threaded serving
runtime (engine/runtime.py, DESIGN.md SS12): runtime workers dispatch
through the same ``_flush_batch`` the synchronous ``flush`` uses, which is
what makes runtime answers bitwise identical to library-mode serving.

Reverse (RkMIPS) serving rides the batched plan/execute pipeline
(DESIGN.md SS9): ``ReverseServer`` accumulates promoted-item queries and
answers them through ``RkMIPSEngine.query_batch`` in fixed-size
micro-batches. Because the flat cross-query work queue made batch size a
pure throughput knob — one trace per batch shape, fast queries' lanes
never idle behind slow ones — online reverse dispatch needs no path of
its own: the server is a ticket queue over the engine.

Invariant (tests/test_serving.py): per-query results are bitwise identical
whether a query is served alone, inside any micro-batch, or in a one-shot
batch — flat-scan rows and RkMIPS work-queue lanes are both independent
and padding is dead, so batching is a latency/throughput knob, never an
accuracy knob.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import sa_alsh as _alsh
from repro.dist.policy import NO_SHARDING, ShardingPolicy
from repro.engine import sharding as _sharding
from repro.engine.artifact import IndexArtifact, corpus_fingerprint
from repro.engine.config import EngineConfig, get_config
from repro.engine.engine import _TraceCount
from repro.kernels import ops as kops


class ServingState(NamedTuple):
    """Everything one config's online scan needs, built offline.

    Item arrays are in descending-norm order (SA-ALSH layout), padded to a
    multiple of the mesh's device count with dead rows, and — under a mesh
    policy — already placed: rows sharded over every axis, the projection
    replicated. ``item_ids`` maps back to the caller's original rows.
    """

    items: jnp.ndarray       # (N_pad, d) f32
    item_ids: jnp.ndarray    # (N_pad,) int32, -1 on padding
    item_mask: jnp.ndarray   # (N_pad,) bool
    codes: jnp.ndarray       # (N_pad, W) uint32
    proj_q: jnp.ndarray      # (d, n_bits) query-side SRP projection
    config: EngineConfig
    n_items: int             # real (unpadded) item count, k's upper bound


class ServeResult(NamedTuple):
    """One served query's answer (values descending; ids in the caller's
    corpus row space — for artifact-backed servers that is artifact id
    space: base rows keep their ids, staged row j is n_base + j)."""

    values: jnp.ndarray
    ids: jnp.ndarray
    k: int


def state_from_index(index, config: EngineConfig | str = "sah", *,
                     policy: ShardingPolicy = NO_SHARDING) -> ServingState:
    """Serving state from an already-built SA-ALSH index — no rebuild.

    Pads the item rows to the mesh's shard multiple and places them
    (rows sharded over every axis, projection replicated); the engine uses
    this to seed a server's cache from its own kMIPS index.
    """
    if isinstance(config, str):
        config = get_config(config)
    arrays = (index.items, index.item_ids, index.item_mask, index.codes)
    n_items = int(index.item_mask.sum())
    proj_q = index.proj[:-1]
    if policy.mesh is not None:
        arrays = _sharding.pad_item_rows(*arrays,
                                         _sharding.n_shards(policy))
        axes = tuple(policy.mesh.axis_names)
        row = lambda x: jax.device_put(x, NamedSharding(
            policy.mesh, P(axes, *([None] * (x.ndim - 1)))))
        arrays = tuple(row(x) for x in arrays)
        proj_q = jax.device_put(proj_q, NamedSharding(policy.mesh, P()))
    return ServingState(*arrays, proj_q=proj_q, config=config,
                        n_items=n_items)


def build_serving_state(items: jnp.ndarray, key: jax.Array,
                        config: EngineConfig | str = "sah", *,
                        policy: ShardingPolicy = NO_SHARDING
                        ) -> ServingState:
    """Offline build: SA-ALSH index -> padded, mesh-placed serving arrays.

    The index build consumes ``key`` exactly as the engine's kMIPS index
    would, so a server and an ``RkMIPSEngine`` handed the same key and
    config scan identical codes.
    """
    if isinstance(config, str):
        config = get_config(config)
    idx = _alsh.build_index(items, key,
                            **config.kmips_build_kwargs(items.shape[0]))
    return state_from_index(idx, config, policy=policy)


def validate_query_rows(q, dim: int | None, what: str) -> jnp.ndarray:
    """Submit-time validation shared by every ticket surface.

    Rejects wrong-dtype / wrong-shape queries with a clear ``ValueError``
    at ``submit`` time — before they sit in the queue — instead of failing
    inside a later flush, which (by the retry contract) would leave the
    whole batch pending behind one malformed row. Returns the query as a
    jnp array (1-D single query or 2-D block).
    """
    q = jnp.asarray(q)
    if not jnp.issubdtype(q.dtype, jnp.floating):
        raise ValueError(f"{what}: queries must have a floating dtype, "
                         f"got {q.dtype}")
    if q.ndim not in (1, 2):
        raise ValueError(f"{what}: queries must be one row (d,) or a "
                         f"block (nq, d), got shape {q.shape}")
    if dim is not None and q.shape[-1] != dim:
        raise ValueError(f"{what}: query dimensionality {q.shape[-1]} != "
                         f"corpus dimensionality {dim}")
    return q


def _index_recipe(config: EngineConfig, n_items: int) -> tuple:
    """The build-kwargs tuple that determines the built serving arrays.

    Derived from ``EngineConfig.kmips_build_kwargs`` — the same recipe
    every builder consumes — so the cache key can never drift from the
    build. Serve-only knobs (batch size, cache capacity) and query-time
    knobs (k, n_cand, scan, ...) do not change the offline build, so
    configs differing only there share one cached state.
    """
    return tuple(sorted(config.kmips_build_kwargs(n_items).items()))


class ServingCache:
    """LRU of built ``ServingState``, keyed by (corpus fingerprint, index
    recipe).

    ``EngineConfig`` is frozen and hashable (engine/config.py), and the
    cache keys on exactly the fields that feed the offline build
    (``_index_recipe``): a hit is guaranteed to return arrays built with
    the requested knobs — the identical arrays, no rebuild (``builds``
    counts actual builds) — and configs that differ only in serve/query
    knobs share one entry instead of thrashing the LRU.

    The key's fingerprint prefix identifies the *corpus version*
    (``IndexArtifact.fingerprint`` for artifact-backed servers,
    ``corpus_fingerprint(items, key)`` otherwise). ``rebind`` points the
    cache at a new live version for a hot swap: old versions' entries stay
    resident under their own fingerprints (swapping back is a hit, subject
    to the LRU), and content changes can never alias onto a stale state.
    """

    def __init__(self, items: jnp.ndarray, key: jax.Array, *,
                 policy: ShardingPolicy = NO_SHARDING, capacity: int = 4,
                 fingerprint: str | None = None):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self._items = items
        self._key = key
        self._policy = policy
        # lazy: a never-swapped server (one corpus version ever) should
        # not pay a full-corpus host hash at construction
        self._fp = fingerprint
        self.capacity = capacity
        self._states: OrderedDict[tuple, ServingState] = OrderedDict()
        self.builds = 0

    def __len__(self) -> int:
        return len(self._states)

    @property
    def fingerprint(self) -> str:
        """Fingerprint of the live corpus version (the current key prefix,
        computed on first use when not supplied)."""
        if self._fp is None:
            self._fp = corpus_fingerprint(self._items, self._key)
        return self._fp

    def rebind(self, items: jnp.ndarray, key: jax.Array, *,
               fingerprint: str | None = None) -> None:
        """Make a new corpus version live (hot swap). Cached states of
        previous versions remain retrievable under their fingerprints."""
        self._items = items
        self._key = key
        self._fp = (fingerprint if fingerprint is not None
                    else corpus_fingerprint(items, key))

    def _recipe(self, config: EngineConfig) -> tuple:
        return (self.fingerprint, _index_recipe(config, self._items.shape[0]))

    def __contains__(self, config: EngineConfig) -> bool:
        return self._recipe(config) in self._states

    def put(self, config: EngineConfig | str, state: ServingState) -> None:
        """Seed the cache with a pre-built state (no build counted) —
        e.g. the engine's own kMIPS index via ``state_from_index``."""
        if isinstance(config, str):
            config = get_config(config)
        recipe = self._recipe(config)
        self._states[recipe] = state
        self._states.move_to_end(recipe)
        while len(self._states) > self.capacity:
            self._states.popitem(last=False)

    def get(self, config: EngineConfig | str) -> ServingState:
        """The state for ``config``: cached on hit, built+inserted on miss
        (evicting the least-recently-used state past capacity)."""
        if isinstance(config, str):
            config = get_config(config)
        recipe = self._recipe(config)
        state = self._states.get(recipe)
        if state is not None:
            self._states.move_to_end(recipe)
            return state
        state = build_serving_state(self._items, self._key, config,
                                    policy=self._policy)
        self.builds += 1
        self._states[recipe] = state
        while len(self._states) > self.capacity:
            self._states.popitem(last=False)
        return state


class _TicketQueue:
    """Shared ticket bookkeeping for the online servers.

    FIFO: ``submit`` enqueues a query (d,) — or a block (nq, d), one
    ticket per row — and returns the ticket(s); a server's ``flush``
    answers every pending ticket in submission order and consumes the
    queue only on success (a failed flush leaves every ticket pending, so
    a retry answers them all). One implementation, so the ticket
    arithmetic and failure contract can never drift between the forward
    and reverse servers.

    ``submit`` validates dtype/shape up front (``validate_query_rows``):
    a malformed query raises immediately instead of poisoning a later
    flush — the queue only ever holds dispatchable rows.
    """

    def __init__(self, dim: int | None = None):
        self._pending: list[jnp.ndarray] = []
        self._next_ticket = 0
        self._dim = dim  # corpus dimensionality; None skips the dim check

    @property
    def pending(self) -> int:
        """Tickets submitted but not yet flushed."""
        return len(self._pending)

    def submit(self, q: jnp.ndarray) -> int | list[int]:
        """Enqueue a query (d,) -> its ticket; (nq, d) -> one per row.

        Tickets are served strictly in submission order by the next
        ``flush``; a ticket's position in flush's result list is
        ``ticket - first_pending_ticket``. Wrong dtype/shape raises a
        ``ValueError`` here, at submit time.
        """
        q = validate_query_rows(q, self._dim, "submit")
        if q.ndim == 1:
            self._pending.append(q)
            self._next_ticket += 1
            return self._next_ticket - 1
        tickets = list(range(self._next_ticket,
                             self._next_ticket + q.shape[0]))
        self._pending.extend(q[i] for i in range(q.shape[0]))
        self._next_ticket += q.shape[0]
        return tickets

    def _serve_one(self, q: jnp.ndarray, flush, what: str):
        """Submit one query (d,) and flush now, returning its answer.
        Pending tickets (if any) are answered by the same flush, in
        submission order."""
        if jnp.asarray(q).ndim != 1:
            raise ValueError(f"{what} serves one query (d,); use "
                             f"submit/flush for batches")
        ticket = self.submit(q)
        first = self._next_ticket - len(self._pending)
        return flush()[ticket - first]


class RetrievalServer(_TicketQueue):
    """Online kMIPS serving: accumulate single queries, answer in batches.

    ``submit`` enqueues a query (d,) — or a block (nq, d), one ticket per
    row — and returns the ticket(s); ``flush(k)`` answers every pending
    ticket, in submission order, by grouping them into micro-batches of
    ``config.serve_batch_size``, padding the last group with zero queries
    (their rows are computed and discarded — static shapes buy one compile
    per batch size), and dispatching each batch through the sharded flat
    scan. ``compile_count`` exposes how many traces the dispatch function
    has cost: it must stay at one per distinct (batch size, k, n_cand,
    scan) tuple, which tests/test_serving.py pins.

    The server owns a ``ServingCache`` over its corpus; per-flush state
    lookup is O(1) on a hit, so swapping ``config`` between flushes (e.g.
    an A/B of presets) costs one build each, once. ``swap(artifact)``
    makes a new corpus version live between flushes (DESIGN.md SS10).

    Artifact-backed servers serve the delta buffer *incrementally*: the
    cached ``ServingState`` is built from (and keyed by) the artifact's
    **base** corpus (``base_fingerprint``), so staged inserts/deletes
    never trigger a state rebuild. Deletions mask rows out of the scan
    (same shapes — the compiled dispatch is reused), staged inserts are
    folded in by an exact jitted scan of the fixed-capacity buffer
    (``sa_alsh.merge_topk`` — one extra executable ever, its capacity
    being static), and answers come back natively in artifact id space.
    Every delta-descendant of one build shares one cached state: a
    streaming ``swap`` is O(1), not O(rebuild).
    """

    def __init__(self, items: jnp.ndarray, key: jax.Array, *,
                 config: EngineConfig | str = "sah",
                 policy: ShardingPolicy = NO_SHARDING,
                 fingerprint: str | None = None,
                 share_dispatch: "RetrievalServer | None" = None):
        super().__init__(dim=items.shape[1])
        if isinstance(config, str):
            config = get_config(config)
        self.config = config
        self.policy = policy
        self.artifact: IndexArtifact | None = None
        # live staged rows (items, mask, qitems, qscale) | (None,) * 4 —
        # the quantized twin rides along so the int8 screen covers churn
        self._delta = (None, None, None, None)
        self._deleted = None         # host (n_base,) bool; None = no deletes
        self._mask_memo = None       # (ServingState, masked item_mask)
        self.cache = ServingCache(items, key, policy=policy,
                                  capacity=config.serve_cache_capacity,
                                  fingerprint=fingerprint)

        if share_dispatch is not None:
            # Adopt the donor's compiled dispatch + trace counter. Both
            # closures are config-free (k/n_cand/scan/n_base/precision
            # arrive as call-time statics; only the sharding policy is
            # baked in), so any two servers on the same mesh share every
            # executable — tenants with identical signatures re-trace
            # nothing.
            donor = share_dispatch
            if not isinstance(donor, RetrievalServer):
                raise TypeError("share_dispatch must be a RetrievalServer, "
                                f"got {type(donor).__name__}")
            if donor.policy.mesh is not policy.mesh:
                raise ValueError(
                    "share_dispatch requires the same sharding policy "
                    "mesh: compiled executables are specialized to it")
            self._traces = donor._traces
            self._dispatch = donor._dispatch
            self._merge = donor._merge
            return

        self._traces = _TraceCount()

        def _scan(items_a, ids_a, mask_a, codes_a, proj_q, queries, *,
                  k, n_cand, scan):
            # Traced once per static signature; the counter increments at
            # trace time only, so it counts compiles, not calls.
            self._traces.n += 1
            ucodes = kops.srp_hash(queries, proj_q)
            return _sharding.kmips_flat_arrays(
                items_a, ids_a, mask_a, codes_a, ucodes, queries, k,
                self.policy, n_cand=n_cand, scan=scan)

        self._dispatch = jax.jit(_scan,
                                 static_argnames=("k", "n_cand", "scan"))

        def _merge(vals, ids, queries, d_items, d_mask, d_qitems,
                   d_qscale, *, k, n_base, scan_precision):
            # Fold-in of the staged delta buffer — the same merge
            # RkMIPSEngine.kmips applies, so ids agree id-for-id. The
            # buffer's capacity is static: one trace per (batch, k,
            # n_base, precision) ever, however much churn streams
            # through. Under scan_precision="int8" the persisted
            # quantized twin screens staged rows first (bitwise-equal
            # contract: sa_alsh.merge_delta_topk).
            self._traces.n += 1
            return _alsh.merge_delta_topk(
                vals, ids, queries, d_items, d_mask, k, n_base,
                d_qitems=d_qitems, d_qscale=d_qscale,
                scan_precision=scan_precision)

        self._merge = jax.jit(
            _merge, static_argnames=("k", "n_base", "scan_precision"))

    @property
    def compile_count(self) -> int:
        """Traces taken through this server's dispatch — shared with
        every server constructed with ``share_dispatch=self``."""
        return self._traces.n

    @classmethod
    def from_artifact(cls, artifact: IndexArtifact, *,
                      policy: ShardingPolicy = NO_SHARDING,
                      share_dispatch: "RetrievalServer | None" = None
                      ) -> "RetrievalServer":
        """A server over an ``IndexArtifact``'s corpus.

        The serving key derivation matches every other kMIPS surface, and
        the cache is keyed by the artifact **base** fingerprint — when the
        artifact's kMIPS index is already built, the cache is seeded from
        it, so the server scans the exact codes the engine ranks with,
        with zero extra builds. Staged deltas ride as an incremental
        overlay (class docstring); answers are natively in **artifact id
        space** (base ids; staged row j is n_base + j), agreeing
        id-for-id with ``RkMIPSEngine.kmips`` even when the artifact
        carries pending deltas.
        """
        items, key, fp = artifact.serving_base()
        srv = cls(items, key, config=artifact.config, policy=policy,
                  fingerprint=fp, share_dispatch=share_dispatch)
        srv._bind_artifact(artifact)
        return srv

    def _bind_artifact(self, artifact: IndexArtifact) -> None:
        self.artifact = artifact
        self._delta = artifact.kmips_delta_quantized()
        deleted = np.asarray(artifact.deleted)
        self._deleted = deleted if deleted.any() else None
        self._mask_memo = None
        if artifact.kmips_index is not None \
                and artifact.config not in self.cache:
            self.cache.put(artifact.config, state_from_index(
                artifact.kmips_index, artifact.config, policy=self.policy))

    def _masked_item_mask(self, state: ServingState) -> jnp.ndarray:
        """The state's scan mask with the artifact's deleted base rows
        retired — same shape, so the compiled dispatch is reused.

        Computed host-side (artifact ``deleted`` is host layout; eager ops
        on mesh-committed arrays are the jax 0.4.x hazard engine/build.py
        documents) and memoized per bound (state, artifact): one O(n)
        pass per swap, zero per flush.
        """
        if self._deleted is None:
            return state.item_mask
        if self._mask_memo is not None and self._mask_memo[0] is state:
            return self._mask_memo[1]
        ids = np.asarray(jax.device_get(state.item_ids))
        dead = (ids >= 0) & self._deleted[np.clip(ids, 0, None)]
        mask = np.asarray(jax.device_get(state.item_mask)) & ~dead
        marr = jnp.asarray(mask)
        if self.policy.mesh is not None:
            axes = tuple(self.policy.mesh.axis_names)
            marr = jax.device_put(marr, NamedSharding(self.policy.mesh,
                                                      P(axes)))
        self._mask_memo = (state, marr)
        return marr

    def swap(self, artifact: IndexArtifact) -> "RetrievalServer":
        """Make a new artifact version live between flushes.

        Pending tickets survive and are answered against the new version
        by the next ``flush``; previously built versions stay in the cache
        under their base fingerprints (swapping back is a hit). Delta
        mutations of the live base are served from the *same* cached
        state — rebind is O(1) — and when a new base's built shapes match
        the live ones, the compiled dispatch is reused — ``compile_count``
        += 0 (pinned in tests).
        """
        items, key, fp = artifact.serving_base()
        self.config = artifact.config
        self.cache.capacity = artifact.config.serve_cache_capacity
        self.cache.rebind(items, key, fingerprint=fp)
        self._dim = items.shape[1]
        self._bind_artifact(artifact)
        return self

    @property
    def batch_size(self) -> int:
        """The micro-batch size — read from the *current* config, so a
        config swapped between flushes brings its own batching along."""
        return self.config.serve_batch_size

    def bucket_for(self, n: int) -> int:
        """The dispatch size ``n`` queries pad up to: the smallest rung of
        ``config.bucket_ladder()`` that fits them. With no buckets
        configured this is always ``serve_batch_size`` — the pre-bucketing
        contract."""
        if not 1 <= n <= self.batch_size:
            raise ValueError(f"group of {n} outside [1, "
                             f"batch_size={self.batch_size}]")
        return next(b for b in self.config.bucket_ladder() if b >= n)

    def _flush_batch(self, group: list, k: int, *,
                     n_cand: int | None = None,
                     scan: str | None = None,
                     pad_to: int | None = None) -> list[ServeResult]:
        """Answer one micro-batch (<= ``batch_size`` queries) through the
        compiled dispatch — THE flush path: the synchronous ``flush`` and
        the threaded runtime's workers (engine/runtime.py) both call this,
        so their answers are bitwise identical by construction (same
        padding, same executables, same delta fold-in).

        ``pad_to`` overrides the padded dispatch size (a ladder rung from
        ``bucket_for``; defaults to the full ``batch_size``). Padding is
        dead either way — zero queries computed and discarded — so a
        bucket-padded dispatch is bitwise equal to the unbucketed one;
        only the static shape (and hence which executable runs) differs.
        """
        state = self.cache.get(self.config)
        bound = (state.n_items if self.artifact is None
                 else self.artifact.n_items)
        if not 1 <= k <= bound:
            raise ValueError(f"k={k} outside [1, {bound}] "
                             f"supported by this corpus")
        n_cand = self.config.n_cand if n_cand is None else n_cand
        scan = self.config.scan if scan is None else scan
        batch = self.batch_size if pad_to is None else pad_to
        if len(group) > batch:
            raise ValueError(f"group of {len(group)} does not fit "
                             f"pad_to={batch}")
        qs = jnp.stack(group)
        if len(group) < batch:
            qs = jnp.concatenate(
                [qs, jnp.zeros((batch - len(group), qs.shape[1]),
                               qs.dtype)])
        vals, ids = self._dispatch(state.items, state.item_ids,
                                   self._masked_item_mask(state),
                                   state.codes, state.proj_q, qs, k=k,
                                   n_cand=n_cand, scan=scan)
        d_items, d_mask, d_qitems, d_qscale = self._delta
        if d_items is not None:
            vals, ids = self._merge(
                vals, ids, qs, d_items, d_mask, d_qitems, d_qscale, k=k,
                n_base=self.artifact.n_base,
                scan_precision=self.config.scan_precision)
        return [ServeResult(vals[j], ids[j], k) for j in range(len(group))]

    def warmup(self, ks, *, n_cands=None, scans=None,
               buckets=None) -> int:
        """Ahead-of-time compile every (bucket, k, n_cand, scan) dispatch
        cell — plus the delta merge when an artifact with live staged rows
        is bound — via ``jit(...).lower().compile()`` (DESIGN.md SS14), so
        the first real request at any ladder rung runs an executable that
        already exists: zero traces after startup, pinned by the runtime's
        ``traces_after_warmup`` counter.

        ``ks`` is the iterable of query-time ks traffic will use;
        ``n_cands``/``scans``/``buckets`` default to the config's single
        n_cand / scan and the full ``bucket_ladder()``. Returns the number
        of cells compiled. Lowering traces the same jitted callables the
        live path calls (``compile_count`` counts these warmup traces
        too), and the populated jit cache is what the live calls hit.
        """
        state = self.cache.get(self.config)
        mask = self._masked_item_mask(state)
        d = state.items.shape[1]
        ks = tuple(ks)
        n_cands = ((self.config.n_cand,) if n_cands is None
                   else tuple(n_cands))
        scans = (self.config.scan,) if scans is None else tuple(scans)
        buckets = (self.config.bucket_ladder() if buckets is None
                   else tuple(buckets))
        # warm the merge off the artifact's raw buffer arrays, not the
        # liveness-gated self._delta: the buffer's capacity/dtypes are
        # fixed, so the executable built here is the one post-warmup
        # churn will hit — staging the first insert must not trace
        art = self.artifact
        cells = 0
        for b in buckets:
            qs = jnp.zeros((b, d), state.items.dtype)
            for k in ks:
                for nc in n_cands:
                    for sc in scans:
                        self._dispatch.lower(
                            state.items, state.item_ids, mask,
                            state.codes, state.proj_q, qs, k=k,
                            n_cand=nc, scan=sc).compile()
                        cells += 1
                if art is not None:
                    vals = jnp.zeros((b, k), state.items.dtype)
                    ids = jnp.zeros((b, k), state.item_ids.dtype)
                    self._merge.lower(
                        vals, ids, qs, art.delta_items, art.delta_mask,
                        art.delta_qitems, art.delta_qscale, k=k,
                        n_base=art.n_base,
                        scan_precision=self.config.scan_precision
                    ).compile()
                    cells += 1
        return cells

    def flush(self, k: int, *, n_cand: int | None = None,
              scan: str | None = None) -> list[ServeResult]:
        """Answer every pending ticket; results in submission order.

        Pending queries are grouped into micro-batches of
        ``serve_batch_size``; the final partial group is padded to the full
        batch size with zero queries so every dispatch reuses the same
        compiled executable. k/n_cand/scan default to the server's config.

        Tickets stay pending until the whole flush succeeds: a failed
        dispatch (or a bad ``k``) raises without consuming the queue, so a
        retry answers every ticket — dispatch is deterministic, no answer
        is lost or doubled.
        """
        if not self._pending:
            return []
        batch = self.batch_size
        queue = list(self._pending)
        out: list[ServeResult] = []
        for i in range(0, len(queue), batch):
            out.extend(self._flush_batch(queue[i:i + batch], k,
                                         n_cand=n_cand, scan=scan))
        del self._pending[:len(queue)]
        return out

    def kmips(self, q: jnp.ndarray, k: int, *, n_cand: int | None = None,
              scan: str | None = None) -> ServeResult:
        """Serve one query now: submit + flush. Pending tickets (if any)
        are answered by the same flush, preserving submission order."""
        return self._serve_one(
            q, lambda: self.flush(k, n_cand=n_cand, scan=scan), "kmips")


class ReverseResult(NamedTuple):
    """One served reverse (RkMIPS) query's answer.

    predictions: (m,) bool in original user rows — which users would see
                 the promoted item in their top-k.
    stats:       this query's row of core/sah.py::QueryStats.
    k:           the k answered.
    truncated:   True iff a scan budget (EngineConfig.scan_budget) stopped
                 this query's execute scan early. A truncated answer is
                 conservative — skipped lanes resolve to "not in the
                 audience" — never silently wrong, and ``funnel`` carries
                 the batch's pruning snapshot so the caller can see how
                 far the scan got.
    funnel:      engine.PruningFunnel for the dispatch that answered this
                 ticket (batch-level; None until filled by the server).
    """

    predictions: jnp.ndarray
    stats: object
    k: int
    truncated: bool = False
    funnel: object = None


class ReverseServer(_TicketQueue):
    """Online RkMIPS serving: accumulate promoted items, answer in batches.

    A ticket queue over ``RkMIPSEngine.query_batch`` — the batched
    plan/execute pipeline IS the online dispatch (DESIGN.md SS9): batch
    size is a pure throughput knob (one trace per batch shape, mixed-query
    chunks load-balance themselves), so reverse serving needs no private
    scan path the way forward serving once did.

    ``submit`` enqueues a query (d,) — or a block (nq, d), one ticket per
    row — and returns the ticket(s); ``flush(k)`` answers every pending
    ticket in submission order, grouping them into micro-batches of
    ``config.serve_batch_size``. The final partial group is padded to the
    full batch size by repeating its first query (a real vector, so every
    bound stays well-behaved; the padded rows are computed and discarded),
    keeping shapes static: the engine's ``rkmips_compile_count`` — exposed
    here as ``compile_count`` — stays at one per distinct (batch size, k),
    pinned by tests/test_serving.py. Per-ticket answers are bitwise the
    matching rows of a one-shot ``query_batch`` (work-queue lanes are
    independent, see core/sah.py).

    Tickets stay pending until a flush succeeds: a failed dispatch (or a
    bad ``k``) raises without consuming the queue, so a retry answers
    every ticket.
    """

    def __init__(self, engine):
        engine.index                      # raises unless built for RkMIPS
        super().__init__(dim=engine.index.users.shape[-1])
        self.engine = engine

    def swap(self, artifact: IndexArtifact) -> "ReverseServer":
        """Make a new artifact version live between flushes (DESIGN.md
        SS10): re-attaches the underlying engine. Pending tickets survive
        and are answered against the new version by the next ``flush``;
        when the new version's shapes match the live ones the engine's
        compiled dispatch is reused (``compile_count`` += 0 — a staged
        delta buffer adds at most one executable ever, its capacity being
        static)."""
        if artifact.users is None:
            # refuse BEFORE touching the engine: a half-applied swap would
            # strand every pending ticket (the retry contract)
            raise RuntimeError(
                "cannot swap a kMIPS-only artifact into a ReverseServer: "
                "the artifact is not built for RkMIPS (users=None)")
        self.engine.attach(artifact)
        self._dim = self.engine.index.users.shape[-1]
        return self

    @property
    def batch_size(self) -> int:
        """Micro-batch size, read from the engine's config."""
        return self.engine.config.serve_batch_size

    @property
    def compile_count(self) -> int:
        """Traces the engine's reverse dispatch has cost (one per distinct
        (batch shape, k); serving adds no executables of its own)."""
        return self.engine.rkmips_compile_count

    def bucket_for(self, n: int) -> int:
        """The dispatch size ``n`` queries pad up to: the smallest rung of
        the engine config's ``bucket_ladder()`` that fits them. With no
        buckets configured this is always ``serve_batch_size``."""
        if not 1 <= n <= self.batch_size:
            raise ValueError(f"group of {n} outside [1, "
                             f"batch_size={self.batch_size}]")
        return next(b for b in self.engine.config.bucket_ladder()
                    if b >= n)

    def warmup(self, ks, *, buckets=None) -> int:
        """Ahead-of-time compile the engine's reverse dispatch at every
        (bucket, k) cell (DESIGN.md SS14) — delegates to
        ``RkMIPSEngine.warmup``, since reverse serving owns no executables
        of its own. Returns the number of cells compiled."""
        buckets = (self.engine.config.bucket_ladder() if buckets is None
                   else tuple(buckets))
        return self.engine.warmup(ks, batch_sizes=buckets)

    def _flush_batch(self, group: list, k: int, *,
                     pad_to: int | None = None) -> list[ReverseResult]:
        """Answer one micro-batch (<= ``batch_size`` queries) through the
        engine's batched dispatch — THE flush path shared by the
        synchronous ``flush`` and the threaded runtime's workers
        (engine/runtime.py): same repeat-padding, same executable, so
        their answers are bitwise identical by construction.

        ``pad_to`` overrides the padded dispatch size (a ladder rung from
        ``bucket_for``; defaults to the full ``batch_size``). Repeat-padded
        rows are computed and discarded and work-queue lanes are
        independent, so a bucket-padded dispatch is bitwise equal to the
        unbucketed one — only the static shape differs."""
        batch = self.batch_size if pad_to is None else pad_to
        if len(group) > batch:
            raise ValueError(f"group of {len(group)} does not fit "
                             f"pad_to={batch}")
        qs = jnp.stack(group)
        if len(group) < batch:
            qs = jnp.concatenate(
                [qs, jnp.broadcast_to(qs[:1], (batch - len(group),)
                                      + qs.shape[1:])])
        res = self.engine.query_batch(qs, k)
        # Per-ticket truncation flag: the stats row carries 1 iff a scan
        # budget skipped lanes of THAT query (core/sah.py trunc_q); the
        # funnel snapshot rides along so truncation is never silent.
        trunc = np.asarray(res.stats.truncated)
        return [
            ReverseResult(res.predictions[j],
                          jax.tree.map(lambda s, j=j: s[j], res.stats),
                          k,
                          truncated=bool(trunc[j] > 0),
                          funnel=res.funnel)
            for j in range(len(group))]

    def flush(self, k: int) -> list[ReverseResult]:
        """Answer every pending ticket; results in submission order."""
        if not self._pending:
            return []
        batch = self.batch_size
        queue = list(self._pending)
        out: list[ReverseResult] = []
        for i in range(0, len(queue), batch):
            out.extend(self._flush_batch(queue[i:i + batch], k))
        del self._pending[:len(queue)]
        return out

    def rkmips(self, q: jnp.ndarray, k: int) -> ReverseResult:
        """Serve one reverse query now: submit + flush. Pending tickets
        (if any) are answered by the same flush, in submission order."""
        return self._serve_one(q, lambda: self.flush(k), "rkmips")
