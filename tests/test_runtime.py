"""ServingRuntime concurrency contracts (engine/runtime.py, DESIGN.md SS12).

Pins the async-serving guarantees: (1) runtime answers are bitwise the
synchronous ``flush`` on the same ticket stream (forward and reverse), with
compile counts pinned at one trace per batch shape; (2) results never cross
tickets — each future resolves with its own query's row, in admission
order; (3) a ``swap`` lands *between* flushes: an in-flight batch finishes
against the version it was dispatched on, pending tickets survive, and
post-swap tickets answer against the new version with zero retraces;
(4) background compaction never blocks a flush or a mutation — churn that
races the rebuild is re-staged onto the compacted base
(``reconcile_compaction``), and the compacted version persists through the
``keep=`` GC policy; (5) deadlines expire tickets pre-dispatch with
``TicketExpired``; (6) ``drain``/``close`` semantics and submit-time
validation.

Threading discipline: every blocking wait in this file carries an explicit
timeout (no pytest-timeout dependency), and gates patched into the dispatch
path are released in ``finally`` so a failing assert can never wedge the
worker threads.
"""

import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic
from repro.engine import (IndexArtifact, RetrievalServer, RkMIPSEngine,
                          ServingRuntime, TicketExpired, get_config,
                          load_artifact, reconcile_compaction)

D = 16


def _cfg(scan="sketch"):
    return get_config("sah").replace(tile=32, n_bits=32, k_max=8, n_top=8,
                                     leaf_size=8, n_cand=16, scan=scan,
                                     delta_capacity=8, serve_batch_size=4)


_BUILD_KEY = jax.random.PRNGKey(31)


@pytest.fixture(scope="module")
def workload():
    key = jax.random.PRNGKey(23)
    ki, kq = jax.random.split(key)
    items, users = synthetic.recommendation_data(ki, 120, 64, D)
    queries = synthetic.queries_from_items(kq, items, 12)
    return items, users, queries


@pytest.fixture(scope="module")
def artifact(workload):
    items, users, _ = workload
    return IndexArtifact.build(items, users, _BUILD_KEY, config=_cfg())


def _assert_same_serve(got, ref):
    np.testing.assert_array_equal(np.asarray(got.values),
                                  np.asarray(ref.values))
    np.testing.assert_array_equal(np.asarray(got.ids), np.asarray(ref.ids))
    assert got.k == ref.k


def test_forward_runtime_matches_sync_flush_bitwise(workload, artifact):
    """THE async contract: the same ticket stream through the runtime and
    through the library-mode submit+flush resolves bitwise identically,
    ticket for ticket, with the same executables."""
    _, _, queries = workload
    sync = RetrievalServer.from_artifact(artifact)
    sync.submit(queries)
    ref = sync.flush(3)
    rt = ServingRuntime(RetrievalServer.from_artifact(artifact), k=3)
    try:
        tickets = rt.submit(queries)
        for t, r in zip(tickets, ref):
            _assert_same_serve(t.result(timeout=60), r)
            assert t.done() and t.exception(0) is None
            assert t.latency is not None and t.latency >= 0
        st = rt.stats
        assert st.submitted == st.completed == len(queries)
        assert st.expired == 0 and st.failed == 0 and st.batches >= 1
        assert rt.pending == 0
        # same flush path, padded partial batches => same trace count
        assert rt.server.compile_count == sync.compile_count
    finally:
        rt.close()


def test_reverse_runtime_matches_sync_flush_bitwise(workload, artifact):
    """Reverse tickets through the runtime are bitwise the synchronous
    ReverseServer flush — user-space predictions row for row."""
    _, _, queries = workload
    sync = RkMIPSEngine.from_artifact(artifact).reverse_server()
    sync.submit(queries[:8])
    ref = sync.flush(3)
    with RkMIPSEngine.from_artifact(artifact).async_reverse_server(k=3) as rt:
        tickets = rt.submit(queries[:8])
        for t, r in zip(tickets, ref):
            got = t.result(timeout=120)
            np.testing.assert_array_equal(np.asarray(got.predictions),
                                          np.asarray(r.predictions))
            assert got.k == 3
        assert rt.server.compile_count == sync.compile_count
        assert rt.stats.completed == 8


def test_mixed_signature_tickets_fragment_not_corrupt(workload, artifact):
    """Tickets with different k interleaved: batches fragment at signature
    boundaries, but every future still resolves with its own query's
    answer for its own k."""
    _, _, queries = workload
    sync = RetrievalServer.from_artifact(artifact)
    ref = {}
    for k in (2, 5):
        sync.submit(queries)
        ref[k] = sync.flush(k)
    rt = ServingRuntime(RetrievalServer.from_artifact(artifact))
    try:
        ks = [2 if i % 2 == 0 else 5 for i in range(len(queries))]
        tickets = [rt.submit(queries[i], k=k) for i, k in enumerate(ks)]
        for i, (k, t) in enumerate(zip(ks, tickets)):
            got = t.result(timeout=60)
            assert got.k == k
            _assert_same_serve(got, ref[k][i])
        # alternating signatures can never share a micro-batch
        assert rt.stats.batches >= 2
        assert rt.server.compile_count == sync.compile_count
    finally:
        rt.close()


def test_submit_validation_and_ctor_guards(workload, artifact):
    items, _, queries = workload
    with pytest.raises(ValueError, match=r"workers must be >= 1"):
        ServingRuntime(RetrievalServer.from_artifact(artifact), k=3,
                       workers=0)
    with pytest.raises(ValueError, match=r"compact_fill must be in"):
        ServingRuntime(RetrievalServer.from_artifact(artifact), k=3,
                       compact_fill=0.0)
    with pytest.raises(ValueError, match=r"needs artifact_dir="):
        ServingRuntime(RetrievalServer.from_artifact(artifact), k=3, keep=2)
    bare = RetrievalServer(items, jax.random.fold_in(_BUILD_KEY, 9),
                           config=_cfg())
    with pytest.raises(ValueError, match=r"artifact-backed"):
        ServingRuntime(bare, k=3, compaction=True)
    rt = ServingRuntime(RetrievalServer.from_artifact(artifact))
    try:
        with pytest.raises(ValueError, match=r"no k for this ticket"):
            rt.submit(queries[0])
        with pytest.raises(ValueError, match=r"runtime.submit: query "
                                             r"dimensionality"):
            rt.submit(queries[0][:-1], k=3)
        assert rt.pending == 0 and rt.stats.submitted == 0
    finally:
        rt.close()
    with RkMIPSEngine.from_artifact(artifact).async_reverse_server(k=3) \
            as rrt:
        with pytest.raises(ValueError, match=r"forward-serving knobs"):
            rrt.submit(queries[0], n_cand=8)


def test_swap_lands_between_flushes_and_tickets_survive(workload, artifact):
    """Hold the dispatch lock hostage via a gated in-flight batch, swap a
    mutated version underneath: the in-flight batch completes against the
    version it was dispatched on, the blocked swap lands right after, and
    post-swap tickets answer against the new version — zero retraces."""
    _, _, queries = workload
    sync = RetrievalServer.from_artifact(artifact)
    sync.submit(queries[:8])
    ref_old = sync.flush(3)
    # retire the top answers of queries 4/5 so the swap provably matters
    dels = sorted({int(ref_old[4].ids[0]), int(ref_old[5].ids[0])})
    a2 = artifact.delete_items(dels)
    sync.swap(a2)
    sync.submit(queries[4:8])
    ref_new = sync.flush(3)
    assert any(not np.array_equal(np.asarray(a.ids), np.asarray(b.ids))
               for a, b in zip(ref_old[4:], ref_new))

    srv = RetrievalServer.from_artifact(artifact)
    rt = ServingRuntime(srv, k=3, batch_linger=0.0)
    orig = srv._flush_batch
    inflight, gate = threading.Event(), threading.Event()
    armed = [True]

    def gated(group, k, **kw):
        if armed[0]:
            armed[0] = False
            inflight.set()
            assert gate.wait(30)
        return orig(group, k, **kw)

    srv._flush_batch = gated
    swapper = threading.Thread(target=rt.swap, args=(a2,))
    try:
        first = rt.submit(queries[:4])          # exactly one full batch
        assert inflight.wait(10)                # dispatched, gated in-flight
        swapper.start()                         # blocked on the dispatch lock
        time.sleep(0.1)
        assert swapper.is_alive() and not first[0].done()
        gate.set()
        swapper.join(30)
        assert not swapper.is_alive()
        # the in-flight batch was answered on the version it dispatched with
        for t, r in zip(first, ref_old):
            _assert_same_serve(t.result(timeout=30), r)
        # post-swap tickets answer on the new version
        for t, r in zip(rt.submit(queries[4:8]), ref_new):
            _assert_same_serve(t.result(timeout=30), r)
        assert rt.stats.swaps == 1
        # one trace for the (batch, k) shape across both waves: delete-only
        # churn on a same-base version costs zero new executables
        assert srv.compile_count == 1
    finally:
        gate.set()
        srv._flush_batch = orig
        rt.close()
        if swapper.ident is not None:
            swapper.join(5)


def test_compaction_races_mutations_and_never_blocks_flushes(
        workload, artifact, monkeypatch, tmp_path):
    """Gate the off-thread rebuild open: while it runs, tickets resolve and
    mutations stage (compaction never blocks either); when it lands, the
    churn that raced it is re-staged onto the compacted base and the merged
    version is persisted under the keep= GC policy."""
    _, _, queries = workload
    rows = jax.random.normal(jax.random.PRNGKey(7), (5, D)) * 1.1
    started, release = threading.Event(), threading.Event()
    orig_compact = IndexArtifact.compact

    def gated_compact(self, **kw):
        started.set()
        assert release.wait(120)
        return orig_compact(self, **kw)

    monkeypatch.setattr(IndexArtifact, "compact", gated_compact)
    adir = str(tmp_path / "versions")
    rt = ServingRuntime(RetrievalServer.from_artifact(artifact), k=3,
                        compaction=True, compact_fill=1.0,
                        poll_interval=0.01, artifact_dir=adir, keep=2)
    try:
        snapshot = rt.insert_items(rows[:4])
        rt.request_compaction()
        assert started.wait(20)              # compactor snapshotted + building
        # serving keeps flowing while the rebuild runs
        t = rt.submit(queries[0])
        first = t.result(timeout=30)
        # ... and so do mutations, staging onto descendants of the snapshot
        rt.insert_items(rows[4:])
        top = int(first.ids[0])
        rt.delete_items([top])
        assert rt.stats.compactions == 0
        release.set()
        deadline = time.monotonic() + 120
        while rt.stats.compactions < 1:
            assert time.monotonic() < deadline, "compaction never landed"
            time.sleep(0.02)
        merged = rt.artifact
        # merged = compacted snapshot base + exactly the raced churn
        assert merged.n_base == snapshot.n_items
        assert merged.delta_used == 1 and merged.has_pending
        assert merged.n_items == artifact.n_items + 5 - 1
        assert rt.stats.swaps == 4           # 3 mutations + the compaction
        # post-compaction serving == a cold server on the merged version
        ref_srv = RetrievalServer.from_artifact(merged)
        ref_srv.submit(queries[:4])
        refs = ref_srv.flush(3)
        for tt, r in zip(rt.submit(queries[:4]), refs):
            _assert_same_serve(tt.result(timeout=30), r)
        # the merged version was persisted (atomic save, GC-protected)
        deadline = time.monotonic() + 60
        step0 = os.path.join(adir, "step_00000000", "manifest.json")
        while not os.path.exists(step0):
            assert time.monotonic() < deadline, "compacted save never landed"
            time.sleep(0.02)
        assert load_artifact(adir).fingerprint == merged.fingerprint
    finally:
        release.set()
        rt.close()


def test_deadline_expires_tickets_before_dispatch(workload, artifact):
    _, _, queries = workload
    with ServingRuntime(RetrievalServer.from_artifact(artifact), k=3) as rt:
        dead = rt.submit(queries[0], deadline=0.0)
        with pytest.raises(TicketExpired, match=r"missed its deadline"):
            dead.result(timeout=30)
        assert isinstance(dead.exception(1), TicketExpired)
        live = rt.submit(queries[1])         # runtime default: no deadline
        assert live.result(timeout=60).k == 3
        assert rt.drain(timeout=60)
        st = rt.stats
        assert st.expired == 1 and st.completed == 1 and st.failed == 0


def test_dispatch_errors_route_to_futures_not_threads(workload, artifact):
    """A bad k fails the affected tickets with the server's own ValueError
    instead of killing a worker thread; later tickets still complete."""
    _, _, queries = workload
    with ServingRuntime(RetrievalServer.from_artifact(artifact)) as rt:
        bad = rt.submit(queries[0], k=10_000)
        with pytest.raises(ValueError, match=r"outside \[1,"):
            bad.result(timeout=60)
        good = rt.submit(queries[1], k=3)
        assert good.result(timeout=60).k == 3
        st = rt.stats
        assert st.failed == 1 and st.completed == 1


def test_close_drains_then_refuses_new_tickets(workload, artifact):
    _, _, queries = workload
    rt = ServingRuntime(RetrievalServer.from_artifact(artifact), k=3)
    tickets = rt.submit(queries[:6])
    rt.close()                               # drains by default
    for t in tickets:
        assert t.done() and t.exception(0) is None
    with pytest.raises(RuntimeError, match=r"runtime is closed"):
        rt.submit(queries[0])
    rt.close()                               # idempotent
    assert rt.stats.completed == 6 and rt.pending == 0


def test_reconcile_compaction_validates_and_restages(workload):
    """reconcile_compaction unit contracts: identity when nothing raced,
    descendant/monotonicity/delta-free validation, and — the real point —
    the merged version serves the same effective corpus as the raced
    lineage (user-space predictions are id-space-free, so they must be
    bitwise equal under exact scan)."""
    items, users, queries = workload
    art = IndexArtifact.build(items, users, _BUILD_KEY, config=_cfg("exact"))
    rows = jax.random.normal(jax.random.PRNGKey(3), (4, D))
    snap = art.insert_items(rows[:2]).delete_items([5])
    compacted = snap.compact()
    # churn racing the build: one more insert, one base + one staged delete
    cur = snap.insert_items(rows[2:]).delete_items([0, art.n_items + 1])
    assert reconcile_compaction(snap, snap, compacted) is compacted
    with pytest.raises(ValueError, match=r"delta-free compaction"):
        reconcile_compaction(snap, cur, snap)      # still has pending churn
    with pytest.raises(ValueError, match=r"different base build"):
        reconcile_compaction(snap, compacted, compacted)
    with pytest.raises(ValueError, match=r"not monotone"):
        reconcile_compaction(snap, art, compacted)  # ancestor, not descendant

    merged = reconcile_compaction(snap, cur, compacted)
    assert merged.n_base == snap.n_items
    assert merged.delta_used == 2               # rows[2:] re-staged
    assert merged.n_items == cur.n_items
    r_cur = RkMIPSEngine.from_artifact(cur).query_batch(queries, 3)
    r_mrg = RkMIPSEngine.from_artifact(merged).query_batch(queries, 3)
    np.testing.assert_array_equal(np.asarray(r_cur.predictions),
                                  np.asarray(r_mrg.predictions))
