"""Exact (brute-force) oracles for kMIPS and RkMIPS.

Used as ground truth for F1-scores and by property tests. Also the "Simpfer"
inner scan is exact; this module holds the fully dense versions.

Tie/semantics convention (shared by every method in this repo):
  q is in the kMIPS result of u over P u {q}  <=>  #{p in P : <u,p> > <u,q>} <= k-1.
Strictly-greater counting means ties resolve in favor of the query, matching
the paper's Definition 1 where q itself is inserted into the item set.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def kmips(items: jnp.ndarray, queries: jnp.ndarray, k: int
          ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact top-k MIPS. items (n,d), queries (q,d) -> (values, indices) (q,k)."""
    ips = queries @ items.T
    return jax.lax.top_k(ips, k)


def rkmips_decision(items: jnp.ndarray, users: jnp.ndarray,
                    query: jnp.ndarray, k: int,
                    tie_eps: float = 0.0) -> jnp.ndarray:
    """Exact RkMIPS for one query. -> bool (m,): q in kMIPS_k(u, P u {q}).

    tie_eps: items only "beat" tau when ip > tau + tie_eps * ||q||. With
    tie_eps = 0 this is the strict rule; a tiny tie_eps makes the decision
    robust to float accumulation-order noise when queries are drawn from the
    item set (the self-duplicate has ip == tau mathematically and must not
    count; see tests/test_sah_engine.py). Use the same tie_eps in the engine.
    """
    eps = tie_eps * jnp.linalg.norm(query)
    tau = users @ query                       # (m,)
    ips = users @ items.T                     # (m, n)
    beat = jnp.sum(ips > tau[:, None] + eps, axis=-1)
    return beat <= k - 1


def rkmips_batch(items: jnp.ndarray, users: jnp.ndarray,
                 queries: jnp.ndarray, k: int,
                 tie_eps: float = 0.0) -> jnp.ndarray:
    """Exact RkMIPS for a batch of queries -> bool (q, m)."""
    eps = tie_eps * jnp.linalg.norm(queries, axis=-1)     # (q,)
    tau = queries @ users.T                   # (q, m)
    ips = users @ items.T                     # (m, n)
    beat = jnp.sum(ips[None, :, :] > tau[:, :, None] + eps[:, None, None],
                   axis=-1)
    return beat <= k - 1


def rkmips_batch_chunked(items: jnp.ndarray, users: jnp.ndarray,
                         queries: jnp.ndarray, k: int, chunk: int = 4096,
                         tie_eps: float = 0.0) -> jnp.ndarray:
    """Memory-bounded exact RkMIPS oracle (chunks users to avoid q*m*n blowup)."""
    m = users.shape[0]
    outs = []
    fn = jax.jit(rkmips_batch, static_argnames=("k", "tie_eps"))
    for lo in range(0, m, chunk):
        outs.append(fn(items, users[lo:lo + chunk], queries, k,
                       tie_eps=tie_eps))
    return jnp.concatenate(outs, axis=1)
