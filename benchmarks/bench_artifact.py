"""Index-artifact lifecycle costs (DESIGN.md SS10).

What the streaming-delta design trades: between compactions, every reverse
query pays an extra exact scan of the fixed-capacity delta buffer (one
(m_pad, cap) product folded into the plan) — so the interesting numbers are
query latency with a part-full buffer vs after ``compact()``, the compact
(full rebuild) cost itself, and the save/load round-trip the artifact adds
over keeping the index trapped in one process. ``traces`` rows pin the
one-extra-compile-ever story per cell.

    PYTHONPATH=src python -m benchmarks.run --scale smoke --only artifact
"""

from __future__ import annotations

import tempfile
import time

import jax

from benchmarks import common


def _timed_query(eng, queries, k):
    eng.query_batch(queries, k)                          # warm (compile)
    return eng.query_batch(queries, k).seconds / queries.shape[0]


def run(n=2048, m=4096, d=64, nq=8, k=10, cap=256):
    from repro.engine import IndexArtifact, RkMIPSEngine, get_config

    wl = common.make_workload("nmf", n, m, d, nq, (k,))
    cfg = get_config("sah").replace(k_max=50, delta_capacity=cap)
    rows = []

    t0 = time.perf_counter()
    art = IndexArtifact.build(wl.items, wl.users, jax.random.PRNGKey(1),
                              config=cfg)
    jax.block_until_ready(art.index.users)
    t_build = time.perf_counter() - t0
    rows.append(common.fmt_row("artifact/build", t_build * 1e6,
                               f"n={n};m={m};cap={cap}"))

    eng = RkMIPSEngine.from_artifact(art)
    dt_base = _timed_query(eng, wl.queries, k)
    rows.append(common.fmt_row(
        f"artifact/query/base/k={k}", dt_base * 1e6,
        f"traces={eng.rkmips_compile_count};fill=0/{cap}"))

    # half-full delta buffer: staged rows drawn like the corpus, plus a
    # sprinkle of deletions so both adjustment paths are on the clock
    kd = jax.random.PRNGKey(7)
    staged = jax.random.permutation(kd, wl.items)[: cap // 2] * 1.01
    a = art.insert_items(staged).delete_items(list(range(0, n, n // 16)))
    eng.attach(a)
    dt_delta = _timed_query(eng, wl.queries, k)
    rows.append(common.fmt_row(
        f"artifact/query/delta/k={k}", dt_delta * 1e6,
        f"traces={eng.rkmips_compile_count};fill={cap // 2}/{cap};"
        f"overhead_vs_base={dt_delta / dt_base:.2f}"))

    t0 = time.perf_counter()
    ac = a.compact()
    jax.block_until_ready(ac.index.users)
    t_compact = time.perf_counter() - t0
    rows.append(common.fmt_row("artifact/compact", t_compact * 1e6,
                               f"n_eff={ac.n_base}"))
    eng.attach(ac)
    dt_comp = _timed_query(eng, wl.queries, k)
    rows.append(common.fmt_row(
        f"artifact/query/compacted/k={k}", dt_comp * 1e6,
        f"traces={eng.rkmips_compile_count};"
        f"speedup_vs_delta={dt_delta / dt_comp:.2f}"))

    # persistence round-trip (host-gathered npz + manifest, SS6)
    with tempfile.TemporaryDirectory() as tmp:
        t0 = time.perf_counter()
        ac.save(tmp)
        t_save = time.perf_counter() - t0
        t0 = time.perf_counter()
        IndexArtifact.load(tmp)
        t_load = time.perf_counter() - t0
    rows.append(common.fmt_row("artifact/save", t_save * 1e6,
                               f"n={ac.n_base};m={m}"))
    rows.append(common.fmt_row("artifact/load", t_load * 1e6,
                               "fingerprint-verified"))
    return rows
