"""Sharded EmbeddingBag: the recsys hot path, built from first principles.

JAX has no nn.EmbeddingBag and no CSR sparse; lookup is jnp.take +
jax.ops.segment_sum (task spec: "this IS part of the system"). All fields
share one concatenated table (total_rows, dim) with per-field row offsets.

Distribution (DESIGN.md SS5): mod-row sharding over the 'model' axis via
shard_map. Shard r owns rows [r*R, (r+1)*R); it looks up the ids it owns
(masked take) and contributes zeros elsewhere; one psum('model') assembles the
full (B_local, n_fields, dim) bag. Collective bytes per step:
B_local * F * D * 4 * (tp-1)/tp -- independent of table size, which is what
makes 10^8-row tables shardable.

With mesh=None (or tp == 1) the same code runs as a plain take.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist.policy import NO_SHARDING, ShardingPolicy


@dataclasses.dataclass(frozen=True)
class EmbeddingConfig:
    vocab_sizes: tuple[int, ...]      # rows per field
    dim: int
    dtype: object = jnp.float32

    @property
    def n_fields(self) -> int:
        return len(self.vocab_sizes)

    @property
    def total_rows(self) -> int:
        return int(sum(self.vocab_sizes))

    @property
    def offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.vocab_sizes)[:-1]]
                              ).astype(np.int32)


def init_table(key: jax.Array, cfg: EmbeddingConfig,
               pad_to: int = 1) -> jnp.ndarray:
    """(total_rows padded to `pad_to`, dim) table, N(0, 1/sqrt(dim))."""
    rows = -(-cfg.total_rows // pad_to) * pad_to
    return (jax.random.normal(key, (rows, cfg.dim))
            * cfg.dim ** -0.5).astype(cfg.dtype)


def flatten_ids(ids: jnp.ndarray, cfg: EmbeddingConfig) -> jnp.ndarray:
    """Per-field ids (..., n_fields) -> global table rows (adds offsets)."""
    off = jnp.asarray(cfg.offsets)
    return ids + off


def embedding_bag(table: jnp.ndarray, rows: jnp.ndarray,
                  policy: ShardingPolicy = NO_SHARDING,
                  weights: jnp.ndarray | None = None) -> jnp.ndarray:
    """Gather rows (any leading shape) from the (R, D) table.

    rows (...,) int32 global row ids -> (..., D). With a 'model' mesh axis the
    table is row-sharded and the gather is a masked-local-take + psum.
    weights: optional per-id multipliers (...,) (EmbeddingBag sum weights).
    """
    tp = policy.model_axis_size
    if tp == 1:
        out = jnp.take(table, rows, axis=0)
        if weights is not None:
            out = out * weights[..., None]
        return out

    mesh = policy.mesh
    r_total = table.shape[0]
    assert r_total % tp == 0, (r_total, tp)
    r_local = r_total // tp
    dp = policy.dp_axes()

    def local(table_l, rows_l):
        my = jax.lax.axis_index("model")
        lid = rows_l - my * r_local
        valid = (lid >= 0) & (lid < r_local)
        emb = jnp.take(table_l, jnp.clip(lid, 0, r_local - 1), axis=0)
        emb = jnp.where(valid[..., None], emb, 0.0)
        return jax.lax.psum(emb, "model")

    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    lead = dp if (dp and rows.shape[0] % dp_size == 0) else None
    rows_spec = P(*((lead,) + (None,) * (rows.ndim - 1)))
    out = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P("model", None), rows_spec),
        out_specs=P(*((lead,) + (None,) * rows.ndim)),
        check_vma=False,
    )(table, rows)
    if weights is not None:
        out = out * weights[..., None]
    return out
