"""Optimizers from scratch (no optax dependency): AdamW, SGD-momentum,
global-norm clipping, and a composable transform interface.

Moment tensors are kept in f32 regardless of parameter dtype (bf16 training
keeps optimizer state in full precision -- standard large-scale practice).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]   # (g, state, p) ->
    #                                                       (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(max_norm: float):
    def init(params):
        del params
        return ()

    def update(grads, state, params=None):
        del params
        g = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
        return jax.tree.map(lambda x: x * scale, grads), state

    return Optimizer(init, update)


def adamw(lr: float | Callable[[jnp.ndarray], jnp.ndarray], *,
          b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    """AdamW (decoupled weight decay). lr may be a schedule fn of step."""

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr
        b1t = 1.0 - b1 ** step.astype(jnp.float32)
        b2t = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / b1t
            vh = v / b2t
            u = -lr_t * (mh / (jnp.sqrt(vh) + eps)
                         + weight_decay * p.astype(jnp.float32))
            return u, m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda t: t[0], out,
                               is_leaf=lambda t: isinstance(t, tuple))
        m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
        v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


def adafactor(lr: float | Callable = 1e-3, *, b1: float | None = 0.9,
              decay: float = 0.999, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              momentum_dtype=jnp.bfloat16) -> Optimizer:
    """Adafactor (Shazeer & Stern 2018): factored second moment.

    For >=2-D leaves the second moment is stored as row/col means (O(d+f)
    instead of O(d*f) state -- the standard large-model memory trick; PaLM,
    T5). First moment kept in bf16 (set b1=None to disable). At 132B params
    over 256 chips this is ~0.5 GB/chip of optimizer state vs 8.25 GB for
    AdamW's f32 m+v.
    """

    def _factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] >= 2 and p.shape[-2] >= 2

    def init(params):
        def v_init(p):
            if _factored(p):
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                       jnp.float32)}
            return {"full": jnp.zeros(p.shape, jnp.float32)}

        state = {"v": jax.tree.map(v_init, params,
                                   is_leaf=lambda x: hasattr(x, "ndim")),
                 "step": jnp.zeros((), jnp.int32)}
        if b1 is not None:
            state["m"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, momentum_dtype), params)
        return state

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr
        # Adafactor beta2 schedule (capped by the configured decay)
        beta2 = jnp.minimum(1.0 - step.astype(jnp.float32) ** -0.8, decay)

        def upd(g, v, m, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "r" in v:
                r = beta2 * v["r"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                c = beta2 * v["c"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(r, axis=-1, keepdims=True),
                                    eps)
                vhat = (r[..., None] * c[..., None, :]) / denom[..., None]
                v_new = {"r": r, "c": c}
            else:
                vhat = beta2 * v["full"] + (1 - beta2) * g2
                v_new = {"full": vhat}
            u = g * jax.lax.rsqrt(vhat + eps)
            # relative update clipping (Adafactor eq. 12ish)
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            if m is not None:
                m_new = (b1 * m.astype(jnp.float32) + (1 - b1) * u
                         ).astype(momentum_dtype)
                u = m_new.astype(jnp.float32)
            else:
                m_new = None
            return -lr_t * u, v_new, m_new

        is_v = lambda x: isinstance(x, dict) and ("r" in x or "full" in x)
        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        flat_m = (tdef.flatten_up_to(state["m"]) if b1 is not None
                  else [None] * len(flat_g))
        flat_p = tdef.flatten_up_to(params)
        outs = [upd(g, v, m, p) for g, v, m, p in
                zip(flat_g, flat_v, flat_m, flat_p)]
        updates = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
        v_new = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
        new_state = {"v": v_new, "step": step}
        if b1 is not None:
            new_state["m"] = jax.tree_util.tree_unflatten(
                tdef, [o[2] for o in outs])
        return updates, new_state

    return Optimizer(init, update)


def sgd(lr: float, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"mom": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params=None):
        del params
        mom = jax.tree.map(lambda g, m: momentum * m + g.astype(jnp.float32),
                           grads, state["mom"])
        updates = jax.tree.map(lambda m: -lr * m, mom)
        return updates, {"mom": mom}

    return Optimizer(init, update)


def chain(*opts: Optimizer) -> Optimizer:
    """Sequentially-composed gradient transforms (clip -> adam, etc.)."""

    def init(params):
        return tuple(o.init(params) for o in opts)

    def update(grads, state, params):
        new_states = []
        for o, s in zip(opts, state):
            grads, s = o.update(grads, s, params)
            new_states.append(s)
        return grads, tuple(new_states)

    return Optimizer(init, update)


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr
