"""RkMIPSEngine: the one front door for (R)kMIPS (DESIGN.md SS7, SS10).

The facade owns the full query lifecycle that examples, benchmarks and the
serving stack used to hand-roll from ``core/`` pieces:

    eng = RkMIPSEngine("sah").build(items, users, key)
    res = eng.query_batch(promoted_items, k=10)     # res.predictions (nq, m)
    truth = eng.oracle(promoted_items, k=10)        # same tie_eps, always

Since the artifact redesign (DESIGN.md SS10), *building* is separate from
*serving*: ``build()`` is sugar for "make an ``IndexArtifact``, then
``attach`` it", and an engine can equally be stood up from a saved or
streamed-in artifact version:

    art = IndexArtifact.build(items, users, key, config=cfg)   # offline
    art.save("/ckpt/sah")                                      # ship it
    eng = RkMIPSEngine.from_artifact(IndexArtifact.load("/ckpt/sah"),
                                     policy=mesh_policy)       # any mesh
    eng.attach(art.insert_items(new_rows))                     # hot swap

Guarantees the raw ``core/sah.py`` path does not give:

  * predictions come back in **original user-id space** — the leaf-order /
    ``predictions_to_original`` footgun lives behind the facade;
  * build and query can never disagree on a knob: both read one frozen
    ``EngineConfig`` (including ``tie_eps``, which ``oracle()`` shares);
  * a ``ShardingPolicy`` with a mesh transparently shards the dense tau
    matvec + sketch scans over users (queries) and over items (kmips) —
    ``engine/sharding.py`` — with no caller-visible API change. Artifacts
    are stored host-side and mesh-agnostic; ``attach`` lays them out for
    *this* engine's policy, which is what makes a save on one mesh load
    onto any other (the SS6 elastic-restore story applied to indexes);
  * an attached artifact with staged corpus deltas is served honestly:
    deletions leave the scans, staged inserts are exactly counted from the
    fixed-capacity delta buffer (one extra executable ever), and the
    ``oracle`` answers over the *mutated* corpus.

``core/`` stays purely functional underneath (SS1): the engine holds arrays
and timings, never the other way around.
"""

from __future__ import annotations

import time
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import exact as _exact
from repro.core import sa_alsh as _alsh
from repro.core import sah as _sah
from repro.dist.policy import NO_SHARDING, ShardingPolicy
from repro.engine import artifact as _artifact
from repro.engine import sharding as _sharding
from repro.engine.config import EngineConfig, get_config

# Backward-compat alias; the tag lives with the artifact lifecycle now.
_KMIPS_KEY_TAG = _artifact.KMIPS_KEY_TAG


class PruningFunnel(NamedTuple):
    """Aggregate pruning funnel of one RkMIPS batch, summed over queries:
    blocks -> users -> scan lanes -> tiles (derived from the per-query
    ``QueryStats`` counters the batched driver recovers per lane).

    blocks_total / users_total are nq * (count the counters are measured
    against): alive fractions read directly as funnel stage widths.
    tiles_scanned / chunks are the execute phase's packing diagnostics
    (mixed-query chunks share tile visits, see core/sah.py).
    """

    queries: int
    blocks_total: int
    blocks_alive: int
    users_total: int
    users_alive: int
    decided_no_lb: int
    decided_yes_norm: int
    scan_lanes: int
    tiles_scanned: int
    chunks: int
    truncated: int = 0          # queries the scan budget cut short (SS15)

    def format(self) -> str:
        """One human-readable funnel line (examples/quickstart.py)."""
        tail = (f" ({self.truncated} budget-truncated)"
                if self.truncated else "")
        return (f"{self.queries} queries: "
                f"blocks {self.blocks_alive}/{self.blocks_total} alive -> "
                f"users {self.users_alive}/{self.users_total} alive -> "
                f"scan lanes {self.scan_lanes} "
                f"(no-by-bound {self.decided_no_lb}, "
                f"yes-by-norm {self.decided_yes_norm}) -> "
                f"{self.tiles_scanned} tile-visits in {self.chunks} chunks"
                f"{tail}")


class QueryResult(NamedTuple):
    """One RkMIPS answer, already mapped to original user rows.

    predictions: bool, (m,) for query() / (nq, m) for query_batch().
    stats:       core/sah.py::QueryStats (scalar / (nq,) counters).
    seconds:     wall time of the call, compile included on first use.
    k:           the k answered.
    funnel:      aggregate PruningFunnel over the batch.
    """

    predictions: jnp.ndarray
    stats: _sah.QueryStats
    seconds: float
    k: int
    funnel: PruningFunnel | None = None


class KMIPSResult(NamedTuple):
    """Forward top-k MIPS answer (values descending, original item rows)."""

    values: jnp.ndarray
    ids: jnp.ndarray
    tiles_visited: int
    seconds: float
    k: int


class _TraceCount:
    """Mutable compile counter, shared by every engine/server adopting one
    dispatch (``share_dispatch``): the trace fires inside the *owner's*
    closure, so sharers must read the owner's count, not a private zero."""

    __slots__ = ("n",)

    def __init__(self):
        self.n = 0


class RkMIPSEngine:
    """Config-driven, mesh-aware engine for RkMIPS and kMIPS.

    config: an ``EngineConfig`` or a registry name ("sah", "simpfer", ...).
    policy: sharding policy; ``NO_SHARDING`` (default) is single-device,
            a mesh policy shards users/items over every mesh axis.
    share_dispatch: another ``RkMIPSEngine`` whose compiled reverse
            dispatch (jitted callables + trace counter) this engine adopts
            instead of building its own — the multi-tenant trace-sharing
            seam (DESIGN.md SS15): tenants whose configs agree on every
            query knob (``scan_budget``, an execution-only traced operand,
            may differ) and whose artifacts share shapes then share one
            executable cache, so the second tenant's warmup adds zero
            traces. Requires config equality up to ``scan_budget`` and the
            same mesh.

    The engine serves whatever ``IndexArtifact`` version is currently
    attached (``self.artifact``); ``build()`` both makes and attaches one.
    """

    def __init__(self, config: EngineConfig | str = "sah", *,
                 policy: ShardingPolicy = NO_SHARDING,
                 share_dispatch: "RkMIPSEngine | None" = None):
        if isinstance(config, str):
            config = get_config(config)
        if not isinstance(config, EngineConfig):
            raise TypeError(f"config must be an EngineConfig or a registry "
                            f"name, got {type(config).__name__}")
        self.config = config
        self.policy = policy
        self.build_seconds: float | None = None
        self.artifact: _artifact.IndexArtifact | None = None
        self._index: _sah.SAHIndex | None = None
        self._delta: tuple = (None, None, None, None)
        self._items: jnp.ndarray | None = None
        self._users_unit: jnp.ndarray | None = None
        self._key: jax.Array | None = None
        self.n_users: int | None = None
        # The per-query scan budget rides every dispatch as a TRACED int32
        # operand (never a static): engines differing only in budget hit
        # the same executable.
        self._budget = jnp.asarray(config.scan_budget, jnp.int32)
        self.rkmips_mapped_compile_count = 0

        def _rkmips(index, queries, d_items, d_mask, d_qitems, d_qscale,
                    budget, *, k):
            self._traces.n += 1
            return _sharding.rkmips_batch(index, queries, k, self.policy,
                                          delta_items=d_items,
                                          delta_mask=d_mask,
                                          delta_qitems=d_qitems,
                                          delta_qscale=d_qscale,
                                          scan_budget=budget,
                                          **self.config.query_kwargs())

        def _rkmips_eager(index, queries, d_items, d_mask, d_qitems,
                          d_qscale, budget, *, k):
            # Key on everything the executable cache keys on: the index
            # leaves' shapes too, so a rebuild with new sizes counts its
            # recompile instead of hiding behind an old query signature.
            sig = (queries.shape, str(queries.dtype), k,
                   None if d_items is None else
                   (d_items.shape, str(d_items.dtype)),
                   tuple((l.shape, str(l.dtype))
                         for l in jax.tree.leaves(index)))
            if sig not in self._rkmips_seen:
                self._rkmips_seen.add(sig)
                self._traces.n += 1
            return _sharding.rkmips_batch(index, queries, k, self.policy,
                                          delta_items=d_items,
                                          delta_mask=d_mask,
                                          delta_qitems=d_qitems,
                                          delta_qscale=d_qscale,
                                          scan_budget=budget,
                                          **self.config.query_kwargs())

        def _rkmips_mapped(index, queries, d_items, d_mask, d_qitems,
                           d_qscale, *, k):
            self.rkmips_mapped_compile_count += 1
            return _sah.rkmips_batch_mapped(index, queries, k,
                                            delta_items=d_items,
                                            delta_mask=d_mask,
                                            delta_qitems=d_qitems,
                                            delta_qscale=d_qscale,
                                            **self.config.query_kwargs())

        if share_dispatch is not None:
            donor = share_dispatch
            if not isinstance(donor, RkMIPSEngine):
                raise TypeError(f"share_dispatch expects an RkMIPSEngine, "
                                f"got {type(donor).__name__}")
            # Everything but the budget must agree: the adopted closure
            # reads the DONOR's query_kwargs() at trace time, so any other
            # difference would silently serve the donor's knobs.
            if donor.config.replace(
                    scan_budget=config.scan_budget) != config:
                raise ValueError(
                    "share_dispatch requires configs equal in every field "
                    "except scan_budget (the budget is a traced operand; "
                    "all other query knobs bake into the shared trace)")
            if donor.policy.mesh is not policy.mesh:
                raise ValueError("share_dispatch requires the same "
                                 "sharding policy mesh")
            self._traces = donor._traces
            self._rkmips_seen = donor._rkmips_seen
            self._rkmips_dispatch = donor._rkmips_dispatch
        else:
            # Every reverse query routes through one dispatch of the
            # batched plan/execute pipeline (sharded or not).
            # rkmips_compile_count counts compiles, not calls: exactly one
            # per distinct (batch shape, k) — batch size is a pure
            # throughput knob (pinned by tests/test_batched.py), and an
            # attached delta buffer adds exactly one more signature (its
            # capacity is static, so corpus churn never retraces).
            # Single-device the counter increments at jit trace time
            # (ground truth); under a mesh the shard_map must dispatch
            # eagerly — an *outer* jit staged around it re-triggers the
            # jax 0.4.x while-driver miscompile (wrong predictions, caught
            # by the sharded-equivalence test) — so there the counter keys
            # on distinct dispatch signatures, which is exactly how the
            # XLA executable cache keys its compiles.
            self._traces = _TraceCount()
            self._rkmips_seen: set = set()
            if policy.mesh is None:
                self._rkmips_dispatch = jax.jit(_rkmips,
                                                static_argnames=("k",))
            else:
                self._rkmips_dispatch = _rkmips_eager
        self._rkmips_mapped_dispatch = jax.jit(_rkmips_mapped,
                                               static_argnames=("k",))

    @property
    def rkmips_compile_count(self) -> int:
        """Reverse-dispatch traces so far — shared with every engine in
        this engine's ``share_dispatch`` group (the trace happens in one
        closure, whoever triggered it)."""
        return self._traces.n

    # -- lifecycle ---------------------------------------------------------

    def build(self, items: jnp.ndarray, users: jnp.ndarray | None,
              key: jax.Array) -> "RkMIPSEngine":
        """Index ``items`` (n, d) for ``users`` (m, d). Returns self.

        Sugar for ``attach(IndexArtifact.build(items, users, key,
        config=self.config, policy=self.policy))`` — bit-for-bit the raw
        ``sah.build`` path with this config's kwargs (the staged pipeline
        of engine/build.py; under a mesh policy the row-parallel stages
        shard per ``config.build_sharding``, same artifact bitwise).
        ``users=None`` builds a kMIPS-only engine (no user-side SAH
        index): ``kmips()`` works, ``query*()`` raise. The kMIPS index
        key is derived with the same ``fold_in`` tag whether it is built
        eagerly (users=None) or lazily on first ``kmips()``, so
        ``server()`` and every kMIPS path rank with the identical SRP
        codes. Inputs are validated up front (2-D, floating, matching
        dimensionality; positive build knobs) with a clear ``ValueError``.
        The per-stage wall-time breakdown lands on ``self.build_timings``.
        """
        t0 = time.perf_counter()
        art = _artifact.IndexArtifact.build(items, users, key,
                                            config=self.config,
                                            policy=self.policy)
        self.attach(art)
        self.build_seconds = time.perf_counter() - t0
        return self

    @classmethod
    def from_artifact(cls, artifact: "_artifact.IndexArtifact", *,
                      policy: ShardingPolicy = NO_SHARDING
                      ) -> "RkMIPSEngine":
        """An engine serving ``artifact`` under ``policy`` — the restore /
        hand-off path: the artifact's own config drives every knob, and
        ``attach`` lays its host-side arrays out for this policy's mesh
        (elastic: the saving mesh is irrelevant)."""
        return cls(artifact.config, policy=policy).attach(artifact)

    def attach(self, artifact: "_artifact.IndexArtifact") -> "RkMIPSEngine":
        """Make ``artifact`` the engine's live index version. Returns self.

        Drops every derived product of the previous version, places the
        user/block arrays on the mesh when the policy carries one, and
        wires up the staged-delta buffer (if any). Attaching a same-shape
        version (a hot swap) reuses every compiled executable — the
        dispatch signatures are shape-keyed, and the delta buffer's
        capacity is static.
        """
        if not isinstance(artifact, _artifact.IndexArtifact):
            raise TypeError(f"attach expects an IndexArtifact, got "
                            f"{type(artifact).__name__}")
        # delta_capacity, build_sharding, scan_precision and scan_budget
        # are lifecycle/execution knobs, not build/query recipe fields
        # (engine/config.py): the artifact's own buffer governs, the built
        # content is sharding-independent, both scan precisions predict
        # bitwise alike, and the budget only caps execution, so configs
        # differing only there are interchangeable here
        if artifact.config.replace(
                delta_capacity=self.config.delta_capacity,
                build_sharding=self.config.build_sharding,
                scan_precision=self.config.scan_precision,
                scan_budget=self.config.scan_budget) != self.config:
            raise ValueError(
                "artifact config does not match this engine's config; use "
                "RkMIPSEngine.from_artifact(artifact) (or rebuild the "
                "artifact with the engine's config)")
        self.artifact = artifact
        self._items = artifact.effective_items()
        self._key = artifact.key
        self._index = None
        self._users_unit = None
        self.n_users = None
        if artifact.users is None:
            # no user-side index, but live staged inserts still ride the
            # forward merge (kmips); query_view can't be asked here
            self._delta = artifact.kmips_delta_quantized()
            jax.block_until_ready(artifact.ensure_kmips_index().codes)
            return self
        # query_view owns the delta-liveness rule: the buffer it returns is
        # exactly the one its adjusted top_norms covers (stale-norm safety);
        # the persisted int8 twin rides along for the SS13 reverse screen
        view, d_items, d_mask = artifact.query_view()
        self._delta = ((None, None, None, None) if d_items is None else
                       (d_items, d_mask, artifact.delta_qitems,
                        artifact.delta_qscale))
        if self.policy.mesh is not None:
            view = _sharding.shard_index(view, self.policy)
        jax.block_until_ready(view.users)
        self._index = view
        self.n_users = artifact.n_users
        self._users_unit = artifact.users_unit()
        return self

    def _require_artifact(self) -> "_artifact.IndexArtifact":
        if self.artifact is None:
            raise RuntimeError("engine not built: call "
                               "build(items, users, key) first")
        return self.artifact

    @property
    def index(self) -> _sah.SAHIndex:
        """The attached query view (built arrays; read-only by convention).

        Under a mesh policy this is the padded, device-placed layout; the
        artifact keeps the mesh-agnostic original."""
        if self._index is None:
            raise RuntimeError("engine not built for RkMIPS: call "
                               "build(items, users, key) first")
        return self._index

    @property
    def kmips_index(self) -> _alsh.SAALSHIndex:
        """The full-base-corpus SA-ALSH index (built lazily on first use,
        memoized on the attached artifact)."""
        return self._require_artifact().ensure_kmips_index()

    @property
    def build_timings(self):
        """Per-stage ``BuildTimings`` of the attached artifact's build
        (engine/build.py), or None when the artifact was loaded from disk
        / wired from pieces rather than built this process."""
        return None if self.artifact is None else self.artifact.build_timings

    def _check_k(self, k: int) -> None:
        if not 1 <= k <= self.config.k_max:
            raise ValueError(f"k={k} outside [1, k_max={self.config.k_max}] "
                             f"supported by this index; rebuild with a "
                             f"larger k_max")

    # -- reverse queries ---------------------------------------------------

    def _funnel(self, stats: _sah.QueryStats, nq: int) -> PruningFunnel:
        """Aggregate the per-query counters into one PruningFunnel.

        Sums run host-side on the already-materialized (nq,) counters —
        the result is blocked on before this runs — so building the
        funnel launches no device work (serving flushes call this per
        micro-batch)."""
        tot = lambda x: int(np.asarray(x).sum())
        return PruningFunnel(
            queries=nq,
            blocks_total=nq * self.index.n_blocks,
            blocks_alive=tot(stats.blocks_alive),
            users_total=nq * self.n_users,
            users_alive=tot(stats.users_alive),
            decided_no_lb=tot(stats.n_no_lb),
            decided_yes_norm=tot(stats.n_yes_norm),
            scan_lanes=tot(stats.n_scan),
            tiles_scanned=tot(stats.tiles_scanned),
            chunks=tot(stats.chunks),
            truncated=int((np.asarray(stats.truncated) > 0).sum()))

    def query(self, q: jnp.ndarray, k: int) -> QueryResult:
        """RkMIPS for one query (d,): which users have q in their top-k.

        A batch of one through the same plan/execute dispatch as
        ``query_batch`` (bitwise equal to the per-query reference driver,
        see core/sah.py). Executables are keyed per (batch shape, k), so
        single queries compile their own (1, d) executable — once — and
        every later single query reuses it.
        """
        index = self.index
        self._check_k(k)
        t0 = time.perf_counter()
        pred, stats = self._rkmips_dispatch(index, q[None], *self._delta,
                                            self._budget, k=k)
        pred = pred[0]
        stats = jax.tree.map(lambda s: s[0], stats)
        po = _sah.predictions_to_original(index, pred, self.n_users)
        jax.block_until_ready(po)
        return QueryResult(po, stats, time.perf_counter() - t0, k,
                           self._funnel(stats, 1))

    def query_batch(self, queries: jnp.ndarray, k: int) -> QueryResult:
        """RkMIPS for a batch (nq, d) -> predictions (nq, m).

        One jitted dispatch of the batched plan/execute pipeline
        (core/sah.py, sharded by ``engine/sharding.py`` under a mesh
        policy): one trace per distinct (nq, k) however large the batch —
        ``rkmips_compile_count`` exposes the trace count. Answers reflect
        the attached artifact's staged corpus deltas (DESIGN.md SS10). The
        result's ``funnel`` aggregates the recovered per-query pruning
        counters.
        """
        index = self.index
        self._check_k(k)
        t0 = time.perf_counter()
        pred, stats = self._rkmips_dispatch(index, queries, *self._delta,
                                            self._budget, k=k)
        po = _sah.predictions_to_original(index, pred, self.n_users)
        jax.block_until_ready(po)
        return QueryResult(po, stats, time.perf_counter() - t0, k,
                           self._funnel(stats, queries.shape[0]))

    def query_batch_mapped(self, queries: jnp.ndarray, k: int) -> QueryResult:
        """The legacy ``lax.map``-of-per-query-while-loops batch driver.

        Retained behind the facade as the benchmark baseline the flat-queue
        ``query_batch`` is compared against (benchmarks/bench_rkmips.py) and
        as a second reference for equivalence tests. Single-device only:
        the sharded path is flat-queue only (DESIGN.md SS9).
        """
        index = self.index
        self._check_k(k)
        if self.policy.mesh is not None:
            raise RuntimeError("query_batch_mapped is the single-device "
                               "reference driver; use query_batch under a "
                               "mesh policy")
        t0 = time.perf_counter()
        pred, stats = self._rkmips_mapped_dispatch(index, queries,
                                                   *self._delta, k=k)
        po = _sah.predictions_to_original(index, pred, self.n_users)
        jax.block_until_ready(po)
        return QueryResult(po, stats, time.perf_counter() - t0, k,
                           self._funnel(stats, queries.shape[0]))

    def warmup(self, ks, *, batch_sizes=None) -> int:
        """Ahead-of-time compile the reverse dispatch at every (batch, k)
        cell (DESIGN.md SS14) so the first real query of any warmed shape
        runs an executable that already exists — the serving runtime's
        ``traces_after_warmup == 0`` guarantee.

        ``ks`` is the iterable of query-time ks traffic will use;
        ``batch_sizes`` defaults to the config's ``bucket_ladder()`` (the
        serving dispatch sizes). Single-device this lowers and compiles
        the jitted dispatch per cell (``jit(...).lower().compile()``
        populates the same executable cache live calls hit — the
        maxtext ``aot_compile`` pattern); under a mesh the dispatch is
        eager shard_map (DESIGN.md SS9), so warmup *runs* one dummy batch
        per cell instead, which primes the identical signature-keyed
        cache. ``rkmips_compile_count`` counts warmup traces like any
        others. Returns the number of cells compiled.
        """
        index = self.index                 # raises unless built for RkMIPS
        d = index.users.shape[-1]
        batch_sizes = (self.config.bucket_ladder() if batch_sizes is None
                       else tuple(batch_sizes))
        # warm the live delta signature — and, when the buffer is empty
        # but artifact-backed, the buffer-array signature too: the first
        # post-warmup insert flips self._delta from all-None to the
        # fixed-capacity arrays (plus their int8 twin), and that flip must
        # not trace
        deltas = [self._delta]
        if self.artifact is not None and self._delta[0] is None:
            deltas.append((self.artifact.delta_items,
                           self.artifact.delta_mask,
                           self.artifact.delta_qitems,
                           self.artifact.delta_qscale))
        cells = 0
        for b in batch_sizes:
            qs = jnp.zeros((b, d), index.users.dtype)
            for k in tuple(ks):
                self._check_k(k)
                for delta in deltas:
                    if self.policy.mesh is None:
                        self._rkmips_dispatch.lower(
                            index, qs, *delta, self._budget, k=k).compile()
                    else:
                        pred, _ = self._rkmips_dispatch(index, qs, *delta,
                                                        self._budget, k=k)
                        jax.block_until_ready(pred)
                    cells += 1
        return cells

    # -- forward queries ---------------------------------------------------

    def kmips(self, q: jnp.ndarray, k: int, *,
              n_cand: int | None = None) -> KMIPSResult:
        """Approximate top-k MIPS over the full (mutated) item set.

        q: (d,) or (Q, d). Wraps ``core/sa_alsh.py::kmips_topk`` (tiled,
        early-terminating) on one device; with a mesh policy, the sharded
        single-pass scan of engine/sharding.py — which covers every row,
        so ``tiles_visited`` reports the full tile count there by design.
        Deleted rows are masked out of the scan; staged inserts are folded
        in by a scan of the delta buffer (``sa_alsh.merge_delta_topk``),
        with ids ``n_base + slot`` — under ``scan_precision="int8"`` the
        buffer's persisted quantized twin screens staged rows first, with
        the same bitwise-equal answers. n_cand overrides the config's
        re-rank depth for recall/latency sweeps.
        """
        art = self._require_artifact()
        index = art.kmips_query_view()
        n_cand = self.config.n_cand if n_cand is None else n_cand
        queries = q if q.ndim == 2 else q[None]
        t0 = time.perf_counter()
        if self.policy.mesh is not None:
            vals, ids = _sharding.kmips_flat(index, queries, k, self.policy,
                                             n_cand=n_cand,
                                             scan=self.config.scan)
            tiles = index.tile_max_norm.shape[0]
        else:
            # the tiled scan re-ranks per tile: depth cannot exceed the tile
            vals, ids, tiles = _alsh.kmips_topk(index, queries, k,
                                                n_cand=min(n_cand,
                                                           index.tile),
                                                scan=self.config.scan)
            tiles = int(tiles)
        d_items, d_mask = self._delta[:2]
        if d_items is not None:
            vals, ids = _alsh.merge_delta_topk(
                vals, ids, queries, d_items, d_mask, k, art.n_base,
                d_qitems=art.delta_qitems, d_qscale=art.delta_qscale,
                scan_precision=self.config.scan_precision)
        jax.block_until_ready(vals)
        seconds = time.perf_counter() - t0
        if q.ndim == 1:
            vals, ids = vals[0], ids[0]
        return KMIPSResult(vals, ids, tiles, seconds, k)

    # -- online serving ----------------------------------------------------

    def server(self):
        """An online ``RetrievalServer`` over this engine's attached
        artifact (engine/serving.py, DESIGN.md SS8).

        The server inherits the artifact's config and this engine's
        sharding policy, and its state cache is keyed by the artifact
        fingerprint + index recipe — when the engine's kMIPS index is
        already built (and no deltas are staged), the cache is seeded from
        it, so no second offline build of the same corpus ever happens.
        A new artifact version goes live with ``server.swap(artifact)``.
        """
        from repro.engine import serving as _serving
        return _serving.RetrievalServer.from_artifact(
            self._require_artifact(), policy=self.policy)

    def reverse_server(self):
        """An online ``ReverseServer`` over this engine (engine/serving.py).

        Micro-batched RkMIPS serving as a ticket queue over
        ``query_batch``: the batched plan/execute dispatch is shared, so
        serving costs no extra executables and every answer is bitwise a
        row of the equivalent one-shot batch. Requires a user-side build.
        ``swap(artifact)`` re-attaches between flushes without dropping
        tickets.
        """
        from repro.engine import serving as _serving
        return _serving.ReverseServer(self)

    def async_server(self, **runtime_kwargs):
        """A threaded ``ServingRuntime`` over ``server()`` — forward
        serving as a loop: futures on submit, worker-thread flushes,
        optional background compaction (engine/runtime.py, DESIGN.md
        SS12). Keyword args go to ``ServingRuntime``."""
        from repro.engine import runtime as _runtime
        return _runtime.ServingRuntime(self.server(), **runtime_kwargs)

    def async_reverse_server(self, **runtime_kwargs):
        """A threaded ``ServingRuntime`` over ``reverse_server()`` —
        RkMIPS serving as a loop (engine/runtime.py, DESIGN.md SS12).
        Keyword args go to ``ServingRuntime``."""
        from repro.engine import runtime as _runtime
        return _runtime.ServingRuntime(self.reverse_server(),
                                       **runtime_kwargs)

    # -- ground truth ------------------------------------------------------

    def oracle(self, queries: jnp.ndarray, k: int) -> jnp.ndarray:
        """Exact RkMIPS truth (nq, m) with the engine's own tie_eps — the
        F1 denominator can never drift from the index's tie convention.
        Computed over the attached artifact's *effective* (mutated) corpus,
        so staged deltas are judged against the truth they changed."""
        if self._users_unit is None:
            raise RuntimeError("engine not built for RkMIPS: call "
                               "build(items, users, key) first")
        queries = queries if queries.ndim == 2 else queries[None]
        return _exact.rkmips_batch_chunked(self._items, self._users_unit,
                                           queries, k,
                                           tie_eps=self.config.tie_eps)


def serving_codes(item_vecs: jnp.ndarray, key: jax.Array, *,
                  n_bits: int = 256, config: EngineConfig | None = None
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """DEPRECATED offline sketch build — use the artifact surface instead:

        art = IndexArtifact.build(item_vecs, None, key,
                                  config=cfg.replace(n_bits=n_bits))
        codes, proj_q = art.serving_codes()

    This shim builds exactly that artifact and forwards, so its codes are
    identical to every other kMIPS surface sharing the recipe (the key is
    folded with the shared tag; pre-artifact releases hashed with the raw
    key). Kept one release for ``launch/serve.py``-era callers.
    """
    warnings.warn(
        "repro.engine.serving_codes is deprecated: build an IndexArtifact "
        "and call artifact.serving_codes() (see engine/artifact.py). Note "
        "the codes now derive from fold_in(key, KMIPS_KEY_TAG) — the "
        "shared tag every kMIPS surface uses — and differ from "
        "pre-artifact releases, which hashed with the raw key: regenerate "
        "any persisted codes/projection pair together, never mix releases",
        DeprecationWarning, stacklevel=2)
    cfg = (config or get_config("sah")).replace(n_bits=n_bits)
    art = _artifact.IndexArtifact.build(item_vecs, None, key, config=cfg)
    return art.serving_codes()
