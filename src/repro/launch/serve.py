"""Serving: two-tower retrieval with exact or SAH (sketch) candidate scoring.

The SAH path is the paper's technique deployed inside the serving stack:
candidate item vectors are indexed offline (SAT transform + SRP codes,
norm-descending order); online, a query is hashed (d-dim projection only --
the user transform's appended coordinate is 0) and candidates are ranked by
Hamming distance, the top `n_cand` re-ranked exactly. The sharded scan is
NOT hand-rolled here: every mesh dispatch routes through the engine's
``engine/sharding.py::kmips_flat_arrays`` (local Hamming scan + rerank +
local top-k, one tiny all-gather merge; wire bytes per query P * k * 8,
independent of N) — one proven shard_map for the whole stack, DESIGN.md SS8.
Online request batching/caching on top of the same scan lives in
``repro.engine.serving`` (``RetrievalServer``).

`build_sah_retrieval_cell` returns the dry-run Cell for this path
(two-tower-retrieval x retrieval_cand, variant "sah").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import base as cfg_base
from repro.dist import policy as pol
from repro.launch import cells as cells_lib
from repro.models import recsys as rec_lib

N_BITS = 256      # SRP sketch width for serving (W = 8 uint32 words)


def sah_retrieve_step(params, user_feats, cand_vecs, cand_codes, proj,
                      cfg, policy, *, n_cand: int = 512, k: int = 100):
    """One query against sharded candidates via sketch scan + rerank.

    user_feats (1, Fu) int32; cand_vecs (N, D) f32 sharded over all axes;
    cand_codes (N, W) uint32 (built offline by core/sa_alsh machinery);
    proj (D, B) f32 -- the first-D rows of the SRP projection (query side).
    The scan itself is ``engine/sharding.py::kmips_flat_arrays`` — the same
    mesh-aware path the engine and ``RetrievalServer`` use, so any N shards
    over any mesh (dead-row padding) with no serving-private shard_map.
    """
    from repro.engine import sharding as eng_sharding
    from repro.kernels import ops as kops

    u = rec_lib.user_tower(params, user_feats, cfg, policy)[0]   # (D,)
    qcode = kops.srp_hash(u[None, :], proj)                      # (1, W)
    n = cand_vecs.shape[0]
    vals, ids = eng_sharding.kmips_flat_arrays(
        cand_vecs, jnp.arange(n, dtype=jnp.int32), jnp.ones((n,), bool),
        cand_codes, qcode, u[None, :], k, policy, n_cand=n_cand)
    return vals[0], ids[0]


def build_sah_retrieval_cell(mesh: Mesh | None,
                             cand_dtype=jnp.float32) -> cells_lib.Cell:
    """cand_dtype=jnp.bfloat16 halves rerank HBM bytes (SSPerf cell-1 iter 3:
    the rerank is a 256-dim dot; bf16 keeps recall on the CPU bench)."""
    arch = cfg_base.get("two-tower-retrieval")
    cfg = arch.make_config()
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape) if mesh else None
    policy = pol.ShardingPolicy(
        mesh=mesh, rules={"act_btd": P(dp, None, None)} if mesh else {})
    init, _, _, tables = cells_lib._recsys_fns(arch, cfg, policy)
    params_shape = jax.eval_shape(init, jax.random.key(0))
    pspecs = cells_lib._recsys_param_specs(params_shape, tables, mesh) \
        if mesh else None

    n_pad = cells_lib.CAND_PAD if mesh else 1 << 16
    w = N_BITS // 32

    def step(params, user_feats, cand_vecs, cand_codes, proj):
        return sah_retrieve_step(params, user_feats, cand_vecs, cand_codes,
                                 proj, cfg, policy)

    abstract = (
        params_shape,
        jax.ShapeDtypeStruct((1, cfg.user_embedding.n_fields), jnp.int32),
        jax.ShapeDtypeStruct((n_pad, cfg.out_dim), cand_dtype),
        jax.ShapeDtypeStruct((n_pad, w), jnp.uint32),
        jax.ShapeDtypeStruct((cfg.out_dim, N_BITS), jnp.float32),
    )
    if mesh is None:
        in_sh = out_sh = None
    else:
        all_axes = tuple(mesh.axis_names)
        sh = lambda s: NamedSharding(mesh, s)
        in_sh = (jax.tree.map(lambda s: sh(s), pspecs,
                              is_leaf=lambda x: isinstance(x, P)),
                 sh(P()), sh(P(all_axes, None)), sh(P(all_axes, None)),
                 sh(P()))
        out_sh = (sh(P()), sh(P()))
    return cells_lib.Cell(
        "two-tower-retrieval", "retrieval_cand_sah", step, abstract,
        in_sh, out_sh,
        note="paper technique in serving: SAT+SRP sketch scan (hamming "
             "kernel) + exact rerank, sharded over the full mesh")


def build_candidate_index(item_vecs: jnp.ndarray, key: jax.Array,
                          n_bits: int = N_BITS):
    """Offline index build for serving: codes + query-side projection.

    Builds a kMIPS-only ``IndexArtifact`` (the persistent, hot-swappable
    index unit of DESIGN.md SS10 — callers that want to ship the index
    between processes should keep the artifact and ``save`` it) and reads
    its ``serving_codes``: ``(codes (N, W) uint32, proj_q (D, n_bits))``
    with ``codes[i]`` the sketch of ``item_vecs[i]`` (input row order),
    directly shippable next to ``item_vecs`` as the ``cand_codes`` /
    ``cand_vecs`` operands of ``sah_retrieve_step``.
    """
    from repro.engine import IndexArtifact, get_config
    art = IndexArtifact.build(
        item_vecs, None, key,
        config=get_config("sah").replace(n_bits=n_bits))
    return art.serving_codes()
