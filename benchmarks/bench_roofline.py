"""Roofline table from the dry-run artifacts (results/dryrun/*.json).

Not a paper table -- required by the task: per (arch x shape x mesh) the
three roofline terms, the dominant bottleneck, and MODEL_FLOPS/HLO_FLOPs.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks import common


def run(dryrun_dir="results/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        r = d["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        ratio = d.get("useful_flops_ratio")
        rows.append(common.fmt_row(
            f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}",
            bound * 1e6,
            f"dom={r['dominant']};compute_ms={r['compute_s']*1e3:.2f};"
            f"memory_ms={r['memory_s']*1e3:.2f};"
            f"coll_ms={r['collective_s']*1e3:.2f};"
            f"mem_gib={d['memory']['per_device_total']/2**30:.2f};"
            f"useful={ratio:.3f}" if ratio else
            f"dom={r['dominant']};mem_gib="
            f"{d['memory']['per_device_total']/2**30:.2f}"))
    if not rows:
        rows.append(common.fmt_row("roofline/none", 0.0,
                                   "run launch/dryrun first"))
    return rows
