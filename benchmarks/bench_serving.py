"""Async serving runtime latency (DESIGN.md SS12).

What the threaded pipeline trades and what it must never trade away: a
ticket pays admission + batch formation + completion-thread handoff over
the raw dispatch (the ``sync`` row is the floor), and a background
compaction must NOT stall traffic — the headline contract is p99 ticket
latency *during* an off-thread ``compact()`` staying within ~2x the
steady state (the rebuild runs unlocked; only the final reconcile+swap
takes the dispatch lock). Rows report closed-loop p50 (headline) with
p99, sample counts, and trace counts in ``derived``; the compacting row
carries the p99 ratio against steady state.

    PYTHONPATH=src python -m benchmarks.run --scale smoke --only serving
"""

from __future__ import annotations

import os
import time

import jax

from benchmarks import common


def _pct(lat: list, q: float) -> float:
    s = sorted(lat)
    return s[min(len(s) - 1, int(q * (len(s) - 1) + 0.5))]


def _env() -> str:
    """``cores=...;devices=...`` — stamped on every serving row so a
    baseline row is interpretable without chasing the run's meta block
    (thread-pipeline latency is core-count sensitive)."""
    return f"cores={os.cpu_count()};devices={jax.device_count()}"


def run(n=2048, m=4096, d=64, nq=8, k=10, cap=128, steady_rounds=48):
    from repro.dist.policy import NO_SHARDING
    from repro.engine import IndexArtifact, RkMIPSEngine, get_config

    wl = common.make_workload("nmf", n, m, d, nq, (k,))
    cfg = get_config("sah").replace(k_max=50, delta_capacity=cap)
    art = IndexArtifact.build(wl.items, wl.users, jax.random.PRNGKey(1),
                              config=cfg)
    rows = []

    # floor: the synchronous library path, one query per flush
    sync = RkMIPSEngine.from_artifact(art).reverse_server()
    sync.rkmips(wl.queries[0], k)                        # warm (compile)
    t0 = time.perf_counter()
    for i in range(nq):
        sync.rkmips(wl.queries[i % nq], k)
    dt_sync = (time.perf_counter() - t0) / nq
    rows.append(common.fmt_row(
        f"serving/sync/k={k}", dt_sync * 1e6,
        f"n={n};m={m};traces={sync.compile_count};{_env()}"))

    eng = RkMIPSEngine.from_artifact(art)
    # compact_policy pinned single-device: under --host-devices N the
    # inherited "auto" policy would fan the off-thread rebuild across N
    # virtual devices that share the serving threads' physical cores —
    # pure oversubscription (sharded == single bitwise, PR 6), and it
    # inflates exactly the p99 this bench exists to bound.
    rt = eng.async_reverse_server(k=k, batch_linger=0.0, compaction=True,
                                  compact_fill=0.95, poll_interval=0.01,
                                  compact_policy=NO_SHARDING)
    try:
        for t in rt.submit(wl.queries):                  # warm (compile)
            t.result(timeout=600)

        # steady state: closed loop, one outstanding ticket
        steady = []
        for i in range(steady_rounds):
            t = rt.submit(wl.queries[i % nq])
            t.result(timeout=600)
            steady.append(t.latency)
        rows.append(common.fmt_row(
            f"serving/runtime/steady/k={k}", _pct(steady, 0.5) * 1e6,
            f"p99_us={_pct(steady, 0.99) * 1e6:.1f};"
            f"samples={len(steady)};traces={rt.server.compile_count};"
            f"overhead_vs_sync={_pct(steady, 0.5) / dt_sync:.2f};"
            f"{_env()}"))

        # part-full delta buffer: the closed loop pays the exact buffer
        # scan — THIS is the fair baseline for the compaction ratio (the
        # compacting loop serves the same staged version)
        kd = jax.random.PRNGKey(7)
        staged = jax.random.permutation(kd, wl.items)[: cap // 2] * 1.01
        rt.insert_items(staged)                          # below the fill
        for t in rt.submit(wl.queries):                  # warm delta path
            t.result(timeout=600)
        delta = []
        for i in range(steady_rounds):
            t = rt.submit(wl.queries[i % nq])
            t.result(timeout=600)
            delta.append(t.latency)
        rows.append(common.fmt_row(
            f"serving/runtime/delta/k={k}", _pct(delta, 0.5) * 1e6,
            f"p99_us={_pct(delta, 0.99) * 1e6:.1f};"
            f"samples={len(delta)};fill={cap // 2}/{cap};"
            f"overhead_vs_steady={_pct(delta, 0.5) / _pct(steady, 0.5):.2f};"
            f"{_env()}"))

        # during compaction: keep the closed loop running while the
        # maintenance thread rebuilds the staged corpus off-thread
        t0 = time.perf_counter()
        rt.request_compaction()
        during, i = [], 0
        while rt.stats.compactions == 0:
            t = rt.submit(wl.queries[i % nq])
            t.result(timeout=600)
            during.append(t.latency)
            i += 1
            if time.perf_counter() - t0 > 600:
                raise RuntimeError("compaction never landed")
        t_compact = rt.last_compaction_seconds
        p99_ratio = (_pct(during, 0.99) / _pct(delta, 0.99)
                     if during else float("nan"))
        rows.append(common.fmt_row(
            f"serving/runtime/compacting/k={k}",
            _pct(during or steady, 0.5) * 1e6,
            f"p99_us={_pct(during or steady, 0.99) * 1e6:.1f};"
            f"samples={len(during)};compact_s={t_compact:.2f};"
            f"p99_vs_delta={p99_ratio:.2f};{_env()}"))
        assert rt.artifact.n_base == n + cap // 2        # compaction landed
    finally:
        rt.close()
    return rows
