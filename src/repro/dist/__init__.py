"""Distribution layer: sharding policies and jax API compat shims.

The forward-compat aliases (jax.shard_map / jax.make_mesh on jax versions
that predate them) are installed once by repro/__init__.py, which always
runs before anything in this package imports. See DESIGN.md SS5.
"""

from repro.dist.policy import (
    NO_SHARDING,
    ShardingPolicy,
    lm_rules,
)

__all__ = ["NO_SHARDING", "ShardingPolicy", "lm_rules"]
