"""Bucket ladder + AOT warmup contract (DESIGN.md SS14).

Hypothesis-free mirrors of the serving bucketing invariants (the property
versions over arbitrary ticket-arrival prefixes live in
tests/test_core_properties.py):

  * ``EngineConfig.serve_buckets`` validation and the ``bucket_ladder()``
    shape — ascending rungs, ``serve_batch_size`` always the top one;
  * bucket-padded dispatch (``_flush_batch(pad_to=...)``) is bitwise
    equal to the unbucketed flush for BOTH servers, staged deltas
    included — padding is dead whichever rung it fills to;
  * warmup (``server.warmup`` / ``ServingRuntime(warmup=True)``)
    precompiles every ladder rung: the first post-warmup request at any
    rung — and the first post-warmup churn — adds zero traces, observable
    as ``RuntimeStats.traces_after_warmup == 0``;
  * the runtime's ``bucket_hits`` / ``bucket_pad_rows`` counters account
    for exactly the sub-maximal dispatches and their dead rows.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic
from repro.engine import EngineConfig, IndexArtifact, RkMIPSEngine
from repro.engine.runtime import ServingRuntime

D = 16
_BUILD_KEY = jax.random.PRNGKey(41)


@pytest.fixture(scope="module")
def workload():
    key = jax.random.PRNGKey(17)
    ki, kq = jax.random.split(key)
    items, users = synthetic.recommendation_data(ki, 120, 40, D)
    queries = synthetic.queries_from_items(kq, items, 6)
    return items, users, queries


def _cfg(**over):
    base = dict(k_max=8, n_top=8, leaf_size=8, tile=32, n_bits=32,
                n_cand=16, delta_capacity=8, serve_batch_size=4,
                serve_buckets=(1, 2))
    base.update(over)
    return EngineConfig(**base)


@pytest.fixture(scope="module")
def artifact(workload):
    items, users, _ = workload
    return IndexArtifact.build(items, users, _BUILD_KEY, config=_cfg())


# ---------------------------------------------------------------------------
# Config: serve_buckets validation + the ladder shape.
# ---------------------------------------------------------------------------


def test_serve_buckets_validation():
    with pytest.raises(ValueError, match="serve_buckets"):
        EngineConfig(serve_batch_size=4, serve_buckets=(0, 2))
    with pytest.raises(ValueError, match="serve_buckets"):
        EngineConfig(serve_batch_size=4, serve_buckets=(1, 8))
    with pytest.raises(ValueError, match="serve_buckets"):
        EngineConfig(serve_batch_size=4, serve_buckets=(2, 1))
    with pytest.raises(ValueError, match="serve_buckets"):
        EngineConfig(serve_batch_size=4, serve_buckets=(2, 2))
    with pytest.raises(ValueError, match="serve_buckets"):
        EngineConfig(serve_batch_size=4, serve_buckets=("1",))
    # lists normalize to a tuple (the config stays hashable)
    cfg = EngineConfig(serve_batch_size=4, serve_buckets=[1, 2])
    assert cfg.serve_buckets == (1, 2)
    hash(cfg)


def test_bucket_ladder():
    assert EngineConfig(serve_batch_size=8).bucket_ladder() == (8,)
    cfg = EngineConfig(serve_batch_size=8, serve_buckets=(1, 2, 4))
    assert cfg.bucket_ladder() == (1, 2, 4, 8)
    # a bucket equal to the batch size does not duplicate the top rung
    cfg = EngineConfig(serve_batch_size=8, serve_buckets=(2, 8))
    assert cfg.bucket_ladder() == (2, 8)


def test_bucket_for(artifact):
    srv = RkMIPSEngine.from_artifact(artifact).server()
    assert [srv.bucket_for(n) for n in (1, 2, 3, 4)] == [1, 2, 4, 4]
    rsrv = RkMIPSEngine.from_artifact(artifact).reverse_server()
    assert [rsrv.bucket_for(n) for n in (1, 2, 3, 4)] == [1, 2, 4, 4]
    for bad in (0, 5):
        with pytest.raises(ValueError, match="outside"):
            srv.bucket_for(bad)
        with pytest.raises(ValueError, match="outside"):
            rsrv.bucket_for(bad)


# ---------------------------------------------------------------------------
# Bitwise: bucket-padded dispatch == unbucketed flush, both servers.
# ---------------------------------------------------------------------------


def test_forward_bucket_padding_bitwise(workload, artifact):
    """Every group size, every fitting rung — including the top one —
    answers bitwise like the plain full-batch flush, with staged deltas
    live (the merge path is exercised too)."""
    _, _, queries = workload
    art = artifact.insert_items(jnp.ones((3, D)) * 0.7).delete_items([2])
    srv = RkMIPSEngine.from_artifact(artifact).server().swap(art)
    for n in (1, 2, 3, 4):
        group = [queries[i % queries.shape[0]] for i in range(n)]
        plain = srv._flush_batch(group, 3)
        for rung in (r for r in (1, 2, 4) if r >= n):
            padded = srv._flush_batch(group, 3, pad_to=rung)
            for a, b in zip(plain, padded):
                np.testing.assert_array_equal(np.asarray(a.values),
                                              np.asarray(b.values))
                np.testing.assert_array_equal(np.asarray(a.ids),
                                              np.asarray(b.ids))
    with pytest.raises(ValueError, match="does not fit"):
        srv._flush_batch([queries[0]] * 3, 3, pad_to=2)


def test_reverse_bucket_padding_bitwise(workload, artifact):
    _, _, queries = workload
    rsrv = RkMIPSEngine.from_artifact(artifact).reverse_server()
    for n in (1, 2, 3, 4):
        group = [queries[i % queries.shape[0]] for i in range(n)]
        plain = rsrv._flush_batch(group, 3)
        padded = rsrv._flush_batch(group, 3, pad_to=rsrv.bucket_for(n))
        for a, b in zip(plain, padded):
            np.testing.assert_array_equal(np.asarray(a.predictions),
                                          np.asarray(b.predictions))
    with pytest.raises(ValueError, match="does not fit"):
        rsrv._flush_batch([queries[0]] * 3, 3, pad_to=1)


# ---------------------------------------------------------------------------
# Warmup: zero traces on the first request at every rung, churn included.
# ---------------------------------------------------------------------------


def test_forward_warmup_zero_traces_every_rung(workload, artifact):
    _, _, queries = workload
    srv = RkMIPSEngine.from_artifact(artifact).server()
    cells = srv.warmup((3,))
    # 3 rungs x (1 dispatch + 1 merge): the merge warms off the raw
    # buffer arrays even though no delta is live yet
    assert cells == 6
    base = srv.compile_count
    for n in (1, 2, 3, 4):
        group = [queries[i % queries.shape[0]] for i in range(n)]
        srv._flush_batch(group, 3, pad_to=srv.bucket_for(n))
        assert srv.compile_count == base, f"rung for n={n} traced"
    # post-warmup churn flips the delta merge live: still no trace
    srv.swap(artifact.insert_items(jnp.ones((2, D))))
    srv._flush_batch([queries[0]], 3, pad_to=1)
    assert srv.compile_count == base
    # an unwarmed signature still traces (the counter is live, not wedged)
    srv._flush_batch([queries[0]], 5, pad_to=1)
    assert srv.compile_count == base + 2    # dispatch + merge at k=5


def test_reverse_warmup_zero_traces_every_rung(workload, artifact):
    _, _, queries = workload
    eng = RkMIPSEngine.from_artifact(artifact)
    rsrv = eng.reverse_server()
    # 3 rungs x 1 k x (empty-delta sig + buffer-array sig)
    assert rsrv.warmup((3,)) == 6
    base = rsrv.compile_count
    for n in (1, 2, 3, 4):
        group = [queries[i % queries.shape[0]] for i in range(n)]
        rsrv._flush_batch(group, 3, pad_to=rsrv.bucket_for(n))
        assert rsrv.compile_count == base, f"rung for n={n} traced"
    # churn flips the engine delta from None to the buffer arrays: warmed
    eng.attach(artifact.insert_items(jnp.ones((2, D))))
    rsrv._flush_batch([queries[0]], 3, pad_to=1)
    assert rsrv.compile_count == base


# ---------------------------------------------------------------------------
# Runtime: stats counters + warmup=True end to end.
# ---------------------------------------------------------------------------


def test_runtime_warm_vs_cold_and_bucket_stats(workload, artifact):
    _, _, queries = workload
    warm = ServingRuntime(RkMIPSEngine.from_artifact(artifact).server(),
                          k=3, warmup=True, batch_linger=0.0)
    cold = ServingRuntime(RkMIPSEngine.from_artifact(artifact).server(),
                          k=3, batch_linger=0.0)
    try:
        # submit one at a time (resolving each before the next) so every
        # batch is a single ticket: deterministic rung-1 dispatches
        wt = [warm.submit(queries[i]) for i in range(3)]
        for t in wt:
            t.result(timeout=120)
        ct = []
        for i in range(3):
            t = cold.submit(queries[i])
            t.result(timeout=120)
            ct.append(t)
        ws, cs = warm.stats, cold.stats
        assert ws.traces_after_warmup == 0
        assert cs.traces_after_warmup > 0          # cold paid live traces
        # cold dispatched 3 single-ticket batches, each on rung 1 — every
        # one sub-maximal, no dead rows on an exact rung
        assert cs.bucket_hits == cs.batches == 3
        assert cs.bucket_pad_rows == 0
        # the warm side may have coalesced its burst, but the counters
        # stay coherent: hits never exceed batches, and everything landed
        assert ws.completed == 3
        assert 0 <= ws.bucket_hits <= ws.batches
        for t_warm, t_cold in zip(wt, ct):
            np.testing.assert_array_equal(
                np.asarray(t_warm.result().values),
                np.asarray(t_cold.result().values))
            np.testing.assert_array_equal(
                np.asarray(t_warm.result().ids),
                np.asarray(t_cold.result().ids))
    finally:
        warm.close()
        cold.close()


def test_runtime_unbucketed_ladder_is_pre_bucketing_contract(workload):
    """Without serve_buckets every dispatch pads to the full batch:
    bucket_hits stays 0 and pad rows account for full-batch padding."""
    items, users, queries = workload
    art = IndexArtifact.build(items, users, _BUILD_KEY,
                              config=_cfg(serve_buckets=()))
    rt = ServingRuntime(RkMIPSEngine.from_artifact(art).server(), k=3,
                        batch_linger=0.0)
    try:
        rt.submit(queries[0]).result(timeout=120)
        s = rt.stats
        assert s.bucket_hits == 0
        assert s.bucket_pad_rows == 3              # 1 ticket padded to 4
    finally:
        rt.close()


def test_runtime_rewarmup_rebaselines(workload, artifact):
    _, _, queries = workload
    rt = ServingRuntime(RkMIPSEngine.from_artifact(artifact).server(),
                        k=3, batch_linger=0.0)
    try:
        rt.submit(queries[0]).result(timeout=120)
        assert rt.stats.traces_after_warmup > 0
        rt.warmup()                                # default ks = (k,)
        assert rt.stats.traces_after_warmup == 0
        rt.submit(queries[1]).result(timeout=120)
        assert rt.stats.traces_after_warmup == 0
    finally:
        rt.close()


def test_runtime_warmup_needs_ks(workload, artifact):
    with pytest.raises(ValueError, match="warmup"):
        ServingRuntime(RkMIPSEngine.from_artifact(artifact).server(),
                       warmup=True)
