"""deepfm: 39 sparse fields, embed_dim=10, MLP 400-400-400, FM interaction.
[arXiv:1703.04247]

Vocab layout (Criteo-like power law, ~37M total rows): 3 x 10M + 6 x 1M +
10 x 100k + 20 x 10k. Tables are padded to a 'model'-axis multiple for
mod-row sharding.
"""

import jax.numpy as jnp

from repro.configs import base
from repro.models.embedding import EmbeddingConfig
from repro.models.recsys import CTRConfig

VOCABS = (10_000_000,) * 3 + (1_000_000,) * 6 + (100_000,) * 10 + \
    (10_000,) * 20


def make_config() -> CTRConfig:
    return CTRConfig(
        name="deepfm",
        embedding=EmbeddingConfig(vocab_sizes=VOCABS, dim=10),
        mlp_dims=(400, 400, 400), interaction="fm")


def make_smoke_config() -> CTRConfig:
    return CTRConfig(
        name="deepfm-smoke",
        embedding=EmbeddingConfig(vocab_sizes=(1000, 500, 200, 100), dim=8),
        mlp_dims=(32, 32), interaction="fm")


base.register(base.ArchSpec(
    arch_id="deepfm", family="recsys", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=base.RECSYS_SHAPES,
    source="arXiv:1703.04247",
    notes="SAH used upstream (candidate generation), not inside the ranker"))
