"""repro.core — the paper's algorithms as pure functions (DESIGN.md SS1).

Modules (no state, no meshes, no device binding — those live in
``repro.engine`` and ``repro.launch``):

  transforms   SAT / QNF asymmetric item transforms
  srp          sign-random-projection hashing helpers
  partitions   norm-range partitioning (Algorithm 1 lines 3-6)
  sa_alsh      SA-ALSH index build + sketch/exact scans (Algorithms 1-2)
  cone         cone blocking of users (Algorithm 3, balanced TPU variant)
  simpfer      Simpfer lower-bound arrays and O(1) decisions
  sah          the SAH index and query (Algorithms 4-5)
  exact        brute-force kMIPS / RkMIPS oracles
  metrics      F1 / recall scoring

Application code should normally go through ``repro.engine`` — the
config-driven facade that wraps these into one build/query surface.
"""

from repro.core import (cone, exact, metrics, partitions, sa_alsh, sah,
                        simpfer, srp, transforms)

__all__ = [
    "cone",
    "exact",
    "metrics",
    "partitions",
    "sa_alsh",
    "sah",
    "simpfer",
    "srp",
    "transforms",
]
