"""Jit'd public entry points for the Pallas kernels with CPU dispatch.

On TPU backends the Pallas kernels run compiled; on CPU (this container) the
vectorized jnp oracles from ref.py are used instead -- interpret=True Pallas
execution is reserved for the correctness tests (it runs the kernel body in
Python per grid step, which is far too slow for benchmark workloads).

Set REPRO_FORCE_INTERPRET=1 to route ops through the interpret-mode kernels
(used by integration tests to prove the kernels compose with the full system).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _flash
from repro.kernels import fused_scan as _fused
from repro.kernels import hamming_scan as _hamming
from repro.kernels import ip_topk as _ip_topk
from repro.kernels import ref as _ref
from repro.kernels import srp_hash as _srp


def _use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_INTERPRET"):
        return True
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def hamming_scores(query_codes: jnp.ndarray,
                   item_codes: jnp.ndarray) -> jnp.ndarray:
    """(q, W) x (n, W) uint32 codes -> (q, n) int32 Hamming distances."""
    if _use_pallas():
        q, n = query_codes.shape[0], item_codes.shape[0]
        bq = min(128, q) if q % min(128, q) == 0 else 1
        bn = min(512, n) if n % min(512, n) == 0 else 1
        return _hamming.hamming_scores(query_codes, item_codes, block_q=bq,
                                       block_n=bn, interpret=_interpret())
    return _ref.hamming_scores(query_codes, item_codes)


def fused_scan(ucodes: jnp.ndarray, item_codes: jnp.ndarray,
               item_mask: jnp.ndarray, qitems: jnp.ndarray,
               qscale: jnp.ndarray, users: jnp.ndarray,
               *, n_cand: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused Hamming filter + top-n_cand + dequantized int8 IP per lane.

    (C, W) u32 x (T, W) u32 codes with (T,) mask, (T, d) int8 + (T,) scale
    -> (cand (C, n_cand) int32, qips (C, n_cand) f32). The CPU fallback is
    the lax mirror, not ref.py: identical results (cand bitwise, qips
    bitwise too -- same gather + einsum) but without lax.top_k's sort,
    which dominates the scan on CPU (see BENCH kernel/fused_scan cells).
    """
    if _use_pallas():
        c = users.shape[0]
        bq = min(8, c) if c % min(8, c) == 0 else 1
        return _fused.fused_scan_tiles(ucodes, item_codes, item_mask,
                                       qitems, qscale, users, n_cand=n_cand,
                                       block_q=bq, interpret=_interpret())
    return _fused.fused_scan_lax(ucodes, item_codes, item_mask, qitems,
                                 qscale, users, n_cand=n_cand)


def srp_hash(x: jnp.ndarray, proj: jnp.ndarray) -> jnp.ndarray:
    """(n, d) f32 through (d, B) projection -> (n, B//32) uint32 codes."""
    if _use_pallas():
        n = x.shape[0]
        bn = min(256, n) if n % min(256, n) == 0 else 1
        return _srp.srp_hash(x, proj, block_n=bn, interpret=_interpret())
    return _ref.srp_hash(x, proj)


@functools.partial(jax.jit, static_argnames=("k",))
def _merge_topk(vals: jnp.ndarray, ids: jnp.ndarray, k: int):
    q = vals.shape[0]
    flat_v = vals.reshape(q, -1)
    flat_i = ids.reshape(q, -1)
    best_v, pos = jax.lax.top_k(flat_v, k)
    best_i = jnp.take_along_axis(flat_i, pos, axis=-1)
    return best_v, best_i


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    *, causal: bool = True) -> jnp.ndarray:
    """Fused causal attention: Pallas on TPU, jnp oracle elsewhere.

    The CPU fallback is the O(S^2)-memory oracle -- only smoke-scale shapes
    should take it (the transformer's default stays chunked attention;
    attn_impl='flash' is the TPU deployment path, see models/transformer)."""
    if _use_pallas():
        return _flash.flash_attention(q, k, v, causal=causal,
                                      interpret=_interpret())
    return _ref.flash_attention(q, k, v, causal=causal)


def ip_topk(queries: jnp.ndarray, items: jnp.ndarray, k: int,
            *, block_n: int = 2048) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact top-k inner products: (q, d) x (n, d) -> (vals, ids) (q, k)."""
    if _use_pallas():
        q, n = queries.shape[0], items.shape[0]
        bq = min(128, q) if q % min(128, q) == 0 else 1
        bn = block_n if n % block_n == 0 else (n if n <= block_n else 1)
        if bn >= k and n % bn == 0:
            vals, ids = _ip_topk.ip_topk_tiles(queries, items, k, block_q=bq,
                                               block_n=bn,
                                               interpret=_interpret())
            return _merge_topk(vals, ids, k)
    return _ref.ip_topk(queries, items, k)
