"""Hypothesis property tests on the paper's invariants (Lemmas 1-3, Facts
1-2, Eq. 8), the engine's data-structure invariants, and the sharding-layer
padding contracts (engine/sharding.py: arbitrary user/item counts over
arbitrary shard counts are bitwise-invisible after mask stripping).

CI runs this module in a dedicated job that fails if hypothesis is missing
(.github/workflows/ci.yml) — the importorskip below is only for minimal
installs. Hypothesis-free mirrors of the padding checks, with fixed prime
sizes, live in tests/test_serving.py so tier-1 always exercises them.
"""

import math

import pytest

# Collection must survive minimal installs (no dev requirements); the
# properties themselves run wherever requirements-dev.txt is installed.
hypothesis = pytest.importorskip("hypothesis")
hnp = pytest.importorskip("hypothesis.extra.numpy")
st = pytest.importorskip("hypothesis.strategies")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cone, exact, partitions, sa_alsh, simpfer, srp
from repro.core import transforms as tf
from repro.dist.policy import NO_SHARDING
from repro.engine import sharding as eng_sharding

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow,
                           hypothesis.HealthCheck.data_too_large])
hypothesis.settings.load_profile("ci")

_floats = st.floats(-5.0, 5.0, allow_nan=False, width=32)


def _matrix(rows_min=4, rows_max=48, cols_min=3, cols_max=16):
    return hnp.arrays(
        np.float32,
        st.tuples(st.integers(rows_min, rows_max),
                  st.integers(cols_min, cols_max)),
        elements=_floats)


@hypothesis.given(_matrix())
def test_sat_lands_on_sphere(p):
    """||I(p, c)|| == R for every item (the SAT sphere property)."""
    items = jnp.asarray(p)
    c, r = tf.centroid_and_radius(items)
    out = tf.sat_item_transform(items, c, r)
    norms = jnp.linalg.norm(out, axis=-1)
    np.testing.assert_allclose(np.asarray(norms),
                               np.full(items.shape[0], float(r)),
                               rtol=1e-3, atol=1e-3)


@hypothesis.given(_matrix(rows_min=6), st.integers(0, 3))
def test_sat_cosine_equivalence(p, seed):
    """Eq. 8: cos(I(p,c), U(u)) == <p-c, u> / (R ||u||)."""
    items = jnp.asarray(p)
    key = jax.random.PRNGKey(seed)
    u = jax.random.normal(key, (items.shape[1],))
    hypothesis.assume(float(jnp.linalg.norm(u)) > 1e-3)
    c, r = tf.centroid_and_radius(items)
    hypothesis.assume(float(r) > 1e-3)
    ip = tf.sat_item_transform(items, c, r)
    uu = tf.user_transform(u[None], r / jnp.linalg.norm(u))[0]
    lhs = (ip @ uu) / (jnp.linalg.norm(ip, axis=-1) * jnp.linalg.norm(uu))
    rhs = ((items - c) @ u) / (r * jnp.linalg.norm(u))
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-2, atol=1e-2)


@hypothesis.given(_matrix(rows_min=8), st.integers(0, 5))
def test_mips_shift_invariance(p, seed):
    """Fact 1: argmax_p <p, u> == argmax_p <p - c, u>."""
    items = jnp.asarray(p)
    u = jax.random.normal(jax.random.PRNGKey(seed), (items.shape[1],))
    c = jnp.mean(items, axis=0)
    a = jnp.argmax(items @ u)
    b = jnp.argmax((items - c) @ u)
    # ties can differ: compare achieved values instead of indices
    np.testing.assert_allclose(float((items @ u)[a]),
                               float((items @ u)[b]), rtol=1e-4, atol=1e-4)


@hypothesis.given(hnp.arrays(np.float32, st.integers(5, 200),
                             elements=st.floats(0.0078125, 128.0,
                                                width=32)),
                  st.sampled_from([0.3, 0.5, 0.7]))
def test_norm_partition_invariants(norms, b):
    """Partition j holds norms in (b*M_j, M_j]; ids are monotone."""
    sorted_norms = jnp.sort(jnp.asarray(norms))[::-1]
    pid, n_parts = partitions.assign_partitions(sorted_norms, b, 64)
    pid = np.asarray(pid)
    sn = np.asarray(sorted_norms)
    assert (np.diff(pid) >= 0).all()                     # monotone
    assert pid[0] == 0
    for j in range(int(n_parts)):
        sel = sn[pid == j]
        if sel.size == 0:
            continue
        mj = sel.max()
        assert (sel > b * mj - 1e-6).all()               # range invariant


@hypothesis.given(st.integers(10, 200), st.integers(2, 8), st.integers(0, 3))
def test_cone_bounds_hold(m, d, seed):
    """Lemmas 2-3: node/vector upper bounds dominate every true <u, q>."""
    key = jax.random.PRNGKey(seed)
    ku, kq, kb = jax.random.split(key, 3)
    users = jax.random.normal(ku, (m, d))
    hypothesis.assume(bool(jnp.all(jnp.linalg.norm(users, axis=-1) > 1e-3)))
    uu = users / jnp.linalg.norm(users, axis=-1, keepdims=True)
    q = jax.random.normal(kq, (d,)) * 3.0
    blocks, padded, mask = cone.build_cone_blocks(uu, kb, leaf_size=8)
    node_ub, phi = cone.node_upper_bound(q, blocks)
    vec_ub = cone.vector_upper_bound(jnp.linalg.norm(q), phi, blocks)
    ips = padded[blocks.perm] @ q                        # (m_pad,)
    leaf = blocks.leaf_size
    node_per_user = jnp.repeat(node_ub, leaf)
    # tolerance scales with ||q||: the bounds go through f32 arccos/cos
    # roundtrips (~1e-4 relative); the engine carries the same slack.
    tol = 1e-3 + 2e-4 * float(jnp.linalg.norm(q))
    assert bool(jnp.all(ips <= node_per_user + tol))
    assert bool(jnp.all(ips <= vec_ub + tol))


@hypothesis.given(st.integers(8, 64), st.integers(3, 10), st.integers(0, 3))
def test_lower_bounds_are_lower(n, d, seed):
    """L_u[j] over P' never exceeds the true (j+1)-th largest IP over P."""
    key = jax.random.PRNGKey(seed)
    ki, ku = jax.random.split(key)
    items = jax.random.normal(ki, (n, d))
    users = jax.random.normal(ku, (5, d))
    uu = users / jnp.linalg.norm(users, axis=-1, keepdims=True)
    kmax = min(8, n // 2)
    order = jnp.argsort(-jnp.linalg.norm(items, axis=-1))
    lb = simpfer.user_lower_bounds(uu, items[order[:kmax]], kmax)
    true_topk, _ = jax.lax.top_k(uu @ items.T, kmax)
    assert bool(jnp.all(lb <= true_topk + 1e-4))


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(st.integers(1, 5), st.integers(1, 4),
                  st.sampled_from((1, 9, 64, 4096)),
                  st.sampled_from((4, 8, 16)), st.integers(0, 3),
                  st.booleans())
def test_batched_flat_queue_equals_per_query_oracle(nq, k, chunk, leaf_size,
                                                    seed, all_pruned):
    """DESIGN.md SS9: the batched plan/execute pipeline is bitwise the
    per-query reference driver — predictions and plan-time counters — over
    arbitrary nq / k / chunk / leaf counts, including nq=1 and an
    all-pruned batch (empty work queue). The hypothesis-free mirror lives
    in tests/test_batched.py."""
    from repro.core import sah
    key = jax.random.PRNGKey(seed + 400)
    ki, ku, kq, kb = jax.random.split(key, 4)
    items = jax.random.normal(ki, (72, 8))
    users = jax.random.normal(ku, (45, 8))
    if all_pruned:
        # positive-orthant users: a huge +e0 query gives every user
        # tau >> ||p_1||, so the plan decides the whole batch "yes" and
        # the work queue is empty
        users = jnp.abs(users) + 0.1
    idx = sah.build(items, users, kb, k_max=4, n_top=4, tile=32,
                    leaf_size=leaf_size, n_bits=32)
    if all_pruned:
        queries = jnp.zeros((nq, 8)).at[:, 0].set(1e4)
        assert int(sah.rkmips_plan(idx, queries, k).n_work) == 0
    else:
        rows = jax.random.randint(kq, (nq,), 0, items.shape[0])
        queries = items[rows]            # queries from items: tie-heavy
    bp, bs = sah.rkmips_batch(idx, queries, k, n_cand=16, chunk=chunk)
    if all_pruned:
        assert not np.asarray(bs.n_scan).any()
        assert not np.asarray(bs.chunks).any()
    for i in range(nq):
        pp, ps = sah.rkmips(idx, queries[i], k, n_cand=16, chunk=chunk)
        np.testing.assert_array_equal(np.asarray(bp[i]), np.asarray(pp),
                                      err_msg=f"query {i}")
        for f in ("blocks_alive", "users_alive", "n_no_lb", "n_yes_norm",
                  "n_scan"):
            assert int(np.asarray(getattr(bs, f))[i]) == \
                int(getattr(ps, f)), (i, f)
    if nq == 1:
        # single-query chunking is identical: packing diagnostics too
        _, ps = sah.rkmips(idx, queries[0], k, n_cand=16, chunk=chunk)
        assert int(np.asarray(bs.tiles_scanned)[0]) == int(ps.tiles_scanned)
        assert int(np.asarray(bs.chunks)[0]) == int(ps.chunks)


@hypothesis.given(st.integers(20, 100), st.integers(3, 8),
                  st.integers(1, 5), st.integers(0, 2))
def test_decision_exact_scan_equals_oracle(n, d, k, seed):
    key = jax.random.PRNGKey(seed + 100)
    ki, ku, kq, kb = jax.random.split(key, 4)
    items = jax.random.normal(ki, (n, d))
    users = jax.random.normal(ku, (32, d))
    uu = users / jnp.linalg.norm(users, axis=-1, keepdims=True)
    q = jax.random.normal(kq, (d,)) * 2.0
    from repro.core import sah
    idx = sah.build(items, users, kb, k_max=8, n_top=8, tile=32,
                    leaf_size=8, n_bits=32)
    pred, _ = sah.rkmips(idx, q, k, scan="exact")
    po = sah.predictions_to_original(idx, pred, 32)
    truth = exact.rkmips_decision(items, uu, q, k)
    np.testing.assert_array_equal(np.asarray(po), np.asarray(truth))


# ---------------------------------------------------------------------------
# Sharding-layer padding: arbitrary (non-power-of-two, prime) user/item
# counts over arbitrary shard counts (engine/sharding.py). The sharded
# execution itself is per-shard-local runs of the same code (shard_map
# equivalence is pinned on the 8-device mesh in tests/test_engine.py);
# these properties pin the padding transform the mesh path relies on.
# ---------------------------------------------------------------------------

# Deliberately spans primes and non-powers-of-two, the counts the old
# divisibility ValueError rejected.
_counts = st.one_of(st.integers(10, 120),
                    st.sampled_from((11, 13, 31, 53, 67, 97, 101, 113)))
_shards = st.one_of(st.integers(2, 8), st.sampled_from((3, 5, 7)))


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(_counts, st.integers(24, 96), _shards, st.integers(0, 3))
def test_padded_blocks_match_unpadded(m, n, shards, seed):
    """pad_index: dead duplicate leaves never change predictions, masked
    counters, or the original-id mapping — for any m, n, shard count."""
    from repro.core import sah
    key = jax.random.PRNGKey(seed)
    ki, ku, kq, kb = jax.random.split(key, 4)
    items = jax.random.normal(ki, (n, 8))
    users = jax.random.normal(ku, (m, 8))
    q = jax.random.normal(kq, (8,)) * 2.0
    idx = sah.build(items, users, kb, k_max=4, n_top=4, tile=32,
                    leaf_size=8, n_bits=32)
    pidx = eng_sharding.pad_index(idx, shards)
    assert pidx.n_blocks % shards == 0
    assert pidx.n_users == pidx.n_blocks * (idx.n_users // idx.n_blocks)
    p0, s0 = sah.rkmips(idx, q, 3, n_cand=16)
    p1, s1 = sah.rkmips(pidx, q, 3, n_cand=16)
    np.testing.assert_array_equal(
        np.asarray(sah.predictions_to_original(idx, p0, m)),
        np.asarray(sah.predictions_to_original(pidx, p1, m)))
    for f in ("blocks_alive", "users_alive", "n_no_lb", "n_yes_norm",
              "n_scan"):
        assert int(getattr(s0, f)) == int(getattr(s1, f)), f
    # padding introduces no duplicate and no phantom ids: the unmasked rows
    # carry each original user id exactly once
    ids = np.asarray(pidx.user_ids)[np.asarray(pidx.user_mask)]
    np.testing.assert_array_equal(np.sort(ids), np.arange(m))


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(_counts, _shards, st.integers(1, 5), st.integers(0, 3),
                  st.sampled_from(("sketch", "exact")))
def test_padded_item_rows_match_unpadded(n, shards, k, seed, scan):
    """pad_item_rows: dead rows (-inf scores) never enter a top-k a real
    row could occupy, for any item count over any shard count."""
    key = jax.random.PRNGKey(seed + 31)
    ki, kq, kb = jax.random.split(key, 3)
    items = jax.random.normal(ki, (n, 12))
    queries = jax.random.normal(kq, (3, 12))
    idx = sa_alsh.build_index(items, kb, n_bits=32, tile=32)
    uc = sa_alsh.user_codes(idx, queries)
    padded = eng_sharding.pad_item_rows(idx.items, idx.item_ids,
                                        idx.item_mask, idx.codes, shards, k)
    assert padded[0].shape[0] % shards == 0
    assert padded[0].shape[0] // shards >= k
    v0, i0 = eng_sharding.kmips_flat_arrays(
        idx.items, idx.item_ids, idx.item_mask, idx.codes, uc, queries, k,
        NO_SHARDING, n_cand=256, scan=scan)
    v1, i1 = eng_sharding.kmips_flat_arrays(*padded, uc, queries, k,
                                            NO_SHARDING, n_cand=256,
                                            scan=scan)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
    # dead rows: ids -1, mask off, and the real rows untouched
    ids_p, mask_p = np.asarray(padded[1]), np.asarray(padded[2])
    np.testing.assert_array_equal(ids_p[: idx.item_ids.shape[0]],
                                  np.asarray(idx.item_ids))
    assert (ids_p[idx.item_ids.shape[0]:] == -1).all()
    assert not mask_p[idx.item_ids.shape[0]:].any()


@hypothesis.given(st.integers(10, 60), _shards, st.integers(0, 3))
def test_padding_preserves_original_mapping(m, shards, seed):
    """predictions_to_original is a left inverse of the padded leaf layout:
    a single-user prediction maps back to exactly that user."""
    from repro.core import sah
    key = jax.random.PRNGKey(seed + 7)
    ki, ku, kb = jax.random.split(key, 3)
    items = jax.random.normal(ki, (32, 8))
    users = jax.random.normal(ku, (m, 8))
    idx = sah.build(items, users, kb, k_max=4, n_top=4, tile=32,
                    leaf_size=8, n_bits=32)
    pidx = eng_sharding.pad_index(idx, shards)
    uid = int(jax.random.randint(kb, (), 0, m))
    pred = (pidx.user_ids == uid) & pidx.user_mask
    out = np.asarray(sah.predictions_to_original(pidx, pred, m))
    expect = np.zeros(m, bool)
    expect[uid] = True
    np.testing.assert_array_equal(out, expect)


# ---------------------------------------------------------------------------
# Serving bucket ladder (DESIGN.md SS14): grouping an arbitrary ticket-
# arrival prefix the way the runtime's _next_batch does (same-k runs capped
# at serve_batch_size) and flushing each group at its ladder rung is bitwise
# the unbucketed full-batch flush — both servers, staged delta live. The
# hypothesis-free mirror with fixed group sizes lives in
# tests/test_bucketing.py.
# ---------------------------------------------------------------------------

_bucket_env: dict = {}


def _bucket_servers():
    """Build the shared corpus/servers once — jit caches live on the server
    instances, so examples after the first re-use every executable."""
    if not _bucket_env:
        from repro.engine import EngineConfig, IndexArtifact, RkMIPSEngine
        key = jax.random.PRNGKey(77)
        ki, ku, kq, kb = jax.random.split(key, 4)
        items = jax.random.normal(ki, (48, 8))
        users = jax.random.normal(ku, (16, 8))
        cfg = EngineConfig(k_max=4, n_top=4, leaf_size=8, tile=32,
                           n_bits=32, n_cand=16, delta_capacity=4,
                           serve_batch_size=4, serve_buckets=(1, 2))
        art = IndexArtifact.build(items, users, kb, config=cfg)
        churned = art.insert_items(jnp.ones((2, 8)) * 0.8).delete_items([5])
        _bucket_env["queries"] = jax.random.normal(kq, (5, 8)) * 1.5
        _bucket_env["fwd"] = \
            RkMIPSEngine.from_artifact(art).server().swap(churned)
        _bucket_env["rev"] = \
            RkMIPSEngine.from_artifact(churned).reverse_server()
    return _bucket_env


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(st.lists(
    st.tuples(st.integers(0, 4), st.sampled_from((2, 3))),
    min_size=1, max_size=12))
def test_bucketed_dispatch_bitwise_over_arrival_prefixes(arrivals):
    """Any arrival prefix of (query, k) tickets: runtime-style grouping +
    rung padding answers bitwise like the plain flush, group by group."""
    env = _bucket_servers()
    fwd, rev, queries = env["fwd"], env["rev"], env["queries"]
    batch = fwd.batch_size
    groups, run = [], []
    for qi, k in arrivals:                 # same-k runs, capped at batch
        if run and (run[0][1] != k or len(run) == batch):
            groups.append(run)
            run = []
        run.append((qi, k))
    groups.append(run)
    for run in groups:
        k = run[0][1]
        group = [queries[qi] for qi, _ in run]
        plain = fwd._flush_batch(group, k)
        padded = fwd._flush_batch(group, k,
                                  pad_to=fwd.bucket_for(len(group)))
        for a, b in zip(plain, padded):
            np.testing.assert_array_equal(np.asarray(a.values),
                                          np.asarray(b.values))
            np.testing.assert_array_equal(np.asarray(a.ids),
                                          np.asarray(b.ids))
        rplain = rev._flush_batch(group, k)
        rpadded = rev._flush_batch(group, k,
                                   pad_to=rev.bucket_for(len(group)))
        for a, b in zip(rplain, rpadded):
            np.testing.assert_array_equal(np.asarray(a.predictions),
                                          np.asarray(b.predictions))


@hypothesis.given(st.integers(4, 60), st.integers(1, 4))
def test_pack_unpack_hamming(n, w):
    """Hamming distance of packed codes == sign-bit disagreements."""
    key = jax.random.PRNGKey(n * w)
    signs_a = jax.random.bernoulli(key, 0.5, (n, 32 * w))
    signs_b = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5,
                                   (n, 32 * w))
    ca, cb = srp.pack_signs(signs_a), srp.pack_signs(signs_b)
    d = srp.hamming_distance(ca, cb)
    expect = jnp.sum(signs_a[:, None, :] != signs_b[None, :, :], axis=-1)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(expect))


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(st.integers(40, 90), st.integers(10, 40),
                  st.integers(0, 6), st.integers(0, 8), st.integers(1, 4),
                  st.integers(0, 5))
def test_delta_buffer_exact_equals_from_scratch(n, m, n_ins, n_del, k,
                                                seed):
    """Streaming corpus deltas (engine/artifact.py, DESIGN.md SS10): for
    exact-scan configs, insert_items/delete_items followed by queries are
    bitwise a from-scratch build on the mutated corpus, for any drawn
    corpus size, user count, insert/delete mix and k — before compact();
    and compact() is bitwise a from-scratch build including counters."""
    from repro.engine import IndexArtifact, RkMIPSEngine, get_config
    key = jax.random.PRNGKey(seed * 1009 + n)
    ki, ku, kq, kb, kn, kd = jax.random.split(key, 6)
    items = jax.random.normal(ki, (n, 8))
    users = jax.random.normal(ku, (m, 8))
    queries = jax.random.normal(kq, (2, 8)) * 1.5
    cfg = get_config("exact").replace(tile=16, n_bits=32, k_max=4, n_top=4,
                                      leaf_size=8, delta_capacity=8)
    art = IndexArtifact.build(items, users, kb, config=cfg)
    a = art
    if n_ins:
        a = a.insert_items(jax.random.normal(kn, (n_ins, 8)))
    dels = np.unique(np.asarray(
        jax.random.randint(kd, (n_del,), 0, n + n_ins))) if n_del else []
    if len(dels):
        a = a.delete_items(dels)
    hypothesis.assume(a.n_items > k)           # keep the decision nontrivial
    keep = np.setdiff1d(np.arange(n), [d for d in dels if d < n])
    live = np.asarray(a.delta_mask)[: n_ins] if n_ins else np.zeros(0, bool)
    staged = np.asarray(a.delta_items)[:n_ins][live] if n_ins else \
        np.zeros((0, 8), np.float32)
    mutated = jnp.asarray(np.concatenate([np.asarray(items)[keep], staged]))
    np.testing.assert_array_equal(np.asarray(a.effective_items()),
                                  np.asarray(mutated))
    eng = RkMIPSEngine.from_artifact(a)
    ref = RkMIPSEngine(cfg).build(mutated, users, kb)
    rd = eng.query_batch(queries, k)
    rr = ref.query_batch(queries, k)
    np.testing.assert_array_equal(np.asarray(rd.predictions),
                                  np.asarray(rr.predictions))
    np.testing.assert_array_equal(np.asarray(rd.predictions),
                                  np.asarray(eng.oracle(queries, k)))
    rc = RkMIPSEngine.from_artifact(a.compact()).query_batch(queries, k)
    np.testing.assert_array_equal(np.asarray(rc.predictions),
                                  np.asarray(rr.predictions))
    for f in ("blocks_alive", "users_alive", "n_no_lb", "n_yes_norm",
              "n_scan"):
        np.testing.assert_array_equal(np.asarray(getattr(rc.stats, f)),
                                      np.asarray(getattr(rr.stats, f)), f)
