"""repro.engine — the unified, config-driven, mesh-aware RkMIPS engine.

This package is the only public way to run (R)kMIPS (DESIGN.md SS7):

  * ``EngineConfig`` — one frozen, hashable dataclass for every index-build
    and query knob, including the oracle-shared ``tie_eps``;
  * the method **registry** — the paper's baseline matrix (DESIGN.md SS3) as
    named presets: ``get_config("sah" | "sa-simpfer" | "h2-cone" |
    "h2-simpfer" | "simpfer" | "exact")``;
  * ``RkMIPSEngine`` — build / query / query_batch / kmips / oracle, with
    predictions always in original user-id space and an optional
    ``ShardingPolicy`` that shards the heavy scans over a mesh;
  * ``serving_codes`` — the offline sketch build behind
    ``launch/serve.py::build_candidate_index``.

``core/`` stays purely functional underneath; everything stateful (built
arrays, timings, lazy kMIPS index) lives here.
"""

from repro.engine.config import (EngineConfig, PAPER_BASELINES, TIE_EPS_DEFAULT,
                                 display_name, get_config, method_names,
                                 register)
from repro.engine.engine import (KMIPSResult, QueryResult, RkMIPSEngine,
                                 serving_codes)

__all__ = [
    "EngineConfig",
    "KMIPSResult",
    "PAPER_BASELINES",
    "QueryResult",
    "RkMIPSEngine",
    "TIE_EPS_DEFAULT",
    "display_name",
    "get_config",
    "method_names",
    "register",
    "serving_codes",
]
