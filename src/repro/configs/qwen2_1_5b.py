"""qwen2-1.5b: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
QKV bias. [arXiv:2407.10671; hf]"""

import jax.numpy as jnp

from repro.configs import base
from repro.models.transformer import LMConfig


def make_config() -> LMConfig:
    return LMConfig(
        name="qwen2-1.5b", n_layers=28, d_model=1536, n_heads=12,
        n_kv_heads=2, d_head=128, d_ff=8960, vocab=151936, qkv_bias=True,
        rope_theta=1000000.0, dtype=jnp.bfloat16)


def make_smoke_config() -> LMConfig:
    return LMConfig(
        name="qwen2-smoke", n_layers=2, d_model=96, n_heads=6,
        n_kv_heads=2, d_head=16, d_ff=256, vocab=512, qkv_bias=True,
        dtype=jnp.float32, max_seq=64, attn_chunk=32)


base.register(base.ArchSpec(
    arch_id="qwen2-1.5b", family="lm", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=base.LM_SHAPES,
    tp_heads=False,  # 12 heads % 16 != 0: no head TP (weights still shard)
    pure_dp_train=False, source="arXiv:2407.10671",
    notes="12 heads not divisible by model=16: attention-head activations "
          "stay unsharded on 'model'; FFN/vocab TP still applies"))
