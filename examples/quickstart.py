"""Quickstart: build a SAH index and answer RkMIPS queries.

    PYTHONPATH=src python examples/quickstart.py

Generates an MF-like synthetic recommendation dataset (the paper's data
regime), builds the SAH index (SAT + SRP sketches + cone blocking + Simpfer
lower bounds), answers reverse queries for a handful of promoted items, and
reports F1 against the exact oracle plus pruning statistics.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import exact, metrics, sah
from repro.data import synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-items", type=int, default=8192)
    ap.add_argument("--m-users", type=int, default=16384)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--queries", type=int, default=8)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    ki, kq, kb = jax.random.split(key, 3)
    items, users = synthetic.recommendation_data(
        ki, args.n_items, args.m_users, args.dim)
    queries = synthetic.queries_from_items(kq, items, args.queries)

    print(f"items={args.n_items} users={args.m_users} d={args.dim} "
          f"k={args.k}")
    t0 = time.time()
    index = sah.build(items, users, kb, k_max=50, n_bits=128)
    jax.block_until_ready(index.users)
    print(f"SAH index built in {time.time()-t0:.2f}s "
          f"(partitions={int(index.alsh.n_parts)}, "
          f"cone blocks={index.n_blocks})")

    t0 = time.time()
    pred, stats = sah.rkmips_batch(index, queries, args.k, scan="sketch",
                                   tie_eps=1e-5)
    pred_orig = sah.predictions_to_original(index, pred, args.m_users)
    jax.block_until_ready(pred_orig)
    dt = (time.time() - t0) / args.queries

    uu = users / jnp.linalg.norm(users, axis=-1, keepdims=True)
    truth = exact.rkmips_batch_chunked(items, uu, queries, args.k,
                                       tie_eps=1e-5)
    f1 = metrics.f1_score(pred_orig, truth)
    print(f"\nper-query time: {dt*1e3:.1f} ms   mean F1: "
          f"{float(jnp.mean(f1)):.3f}")
    s = jax.tree.map(lambda x: np.asarray(x).mean(), stats)
    print(f"pruning: blocks alive {s.blocks_alive:.0f}/{index.n_blocks}, "
          f"decided-no by bounds {s.n_no_lb:.0f}, "
          f"decided-yes by norm {s.n_yes_norm:.0f}, "
          f"scanned {s.n_scan:.0f}/{args.m_users} users, "
          f"{s.tiles_scanned:.0f} tile-visits")
    for i in range(min(4, args.queries)):
        res = np.where(np.asarray(pred_orig[i]))[0]
        print(f"query {i}: {len(res)} users would see this item in their "
              f"top-{args.k}: {res[:8].tolist()}{'...' if len(res) > 8 else ''}")


if __name__ == "__main__":
    main()
