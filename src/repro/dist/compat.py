"""Forward-compat shims for the distributed API surface.

Every sharded call site in this repo (models/, launch/, tests/) targets the
modern public API: ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=...,
check_vma=...)`` and ``jax.make_mesh``. On the pinned 0.4.x toolchain,
shard_map still lives under ``jax.experimental.shard_map`` and its residual
check is spelled ``check_rep``. This module resolves whichever implementation
the installed jax provides and exposes one stable ``shard_map`` callable;
``install()`` additionally aliases it onto the ``jax`` namespace so code (and
subprocess test scripts) written against the modern API run unchanged.

install() is idempotent, never overrides a native implementation, and touches
no device state -- safe to run at import time (see launch/mesh.py's
constraint that imports must not initialize the jax backend).
"""

from __future__ import annotations

import jax

_NATIVE_SHARD_MAP = getattr(jax, "shard_map", None)
if _NATIVE_SHARD_MAP is None:
    from jax.experimental.shard_map import shard_map as _EXPERIMENTAL_SHARD_MAP
else:
    _EXPERIMENTAL_SHARD_MAP = None


def shard_map(f, mesh=None, in_specs=None, out_specs=None, *,
              check_vma=None, check_rep=None, **kwargs):
    """jax.shard_map with both spellings of the replication-check kwarg.

    ``check_vma`` (jax >= 0.6) and ``check_rep`` (jax 0.4/0.5) are the same
    knob; whichever is passed is forwarded under the name the installed jax
    understands. All other arguments pass through untouched.
    """
    check = check_vma if check_vma is not None else check_rep
    if check is None:
        check = True
    if _NATIVE_SHARD_MAP is not None:
        return _NATIVE_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check,
                                 **kwargs)
    return _EXPERIMENTAL_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs, check_rep=check,
                                   **kwargs)


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """jax.make_mesh fallback for jax versions that predate it."""
    native = getattr(jax, "make_mesh", None)
    if native is not None and native is not make_mesh:
        try:
            return native(axis_shapes, axis_names, devices=devices)
        except TypeError:       # older signature without devices kwarg
            if devices is None:
                return native(axis_shapes, axis_names)
            raise
    import numpy as np
    devs = list(devices) if devices is not None else jax.devices()
    n = int(np.prod(axis_shapes))
    return jax.sharding.Mesh(
        np.asarray(devs[:n]).reshape(axis_shapes), axis_names)


@jax.custom_jvp
def optimization_barrier(x):
    """Differentiable jax.lax.optimization_barrier.

    jax 0.4.x has no differentiation rule for the barrier primitive; newer
    jax does. The barrier exists to pin layout/scheduling decisions on the
    *primal* value (e.g. stop XLA folding an f32 upcast into a scan carry),
    so the tangent passes through unbarriered -- gradients are unaffected
    either way.
    """
    return jax.lax.optimization_barrier(x)


@optimization_barrier.defjvp
def _optimization_barrier_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return jax.lax.optimization_barrier(x), t


def install() -> None:
    """Alias the modern distributed API onto ``jax`` if it is missing."""
    if getattr(jax, "shard_map", None) is None:
        jax.shard_map = shard_map
    if getattr(jax, "make_mesh", None) is None:
        jax.make_mesh = make_mesh
