"""Distributed-equivalence tests on an 8-device host mesh (subprocess, so
the 1-device default of every other test is untouched).

Checks: mod-sharded EmbeddingBag == plain take; MoE with EP all-to-all ==
local dispatch; sharded GAT segment ops == local; LM train-step loss under
the TP/SP policy == unsharded; elastic checkpoint restore across meshes.
"""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.dist.policy import ShardingPolicy, lm_rules, NO_SHARDING
from repro.models import embedding as emb_lib
from repro.models import moe as moe_lib
from repro.models import gat as gat_lib
from repro.models import transformer as tf_lib
from repro.models.moe import MoEConfig

mesh = jax.make_mesh((2, 4), ("data", "model"))
policy = ShardingPolicy(mesh=mesh, rules=lm_rules(("data",), "model"))

# --- 1. EmbeddingBag: sharded == local ---------------------------------
key = jax.random.PRNGKey(0)
table = jax.random.normal(key, (64, 8))
rows = jax.random.randint(jax.random.fold_in(key, 1), (16, 3), 0, 64)
local = emb_lib.embedding_bag(table, rows, NO_SHARDING)
sharded = emb_lib.embedding_bag(table, rows, policy)
np.testing.assert_allclose(np.asarray(local), np.asarray(sharded),
                           atol=1e-6)
print("embedding OK")

# --- 2. MoE: EP(all_to_all) == local dispatch --------------------------
cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, capacity_factor=8.0)
params = moe_lib.init_moe_params(key, 8, cfg)
x = jax.random.normal(jax.random.fold_in(key, 2), (4, 8, 8))
out_local, aux_l = moe_lib.moe_ffn(x, params, cfg, NO_SHARDING)
rules = {"act_btd": P(("data",), None, None)}
out_ep, aux_e = jax.jit(lambda x: moe_lib.moe_ffn(
    x, params, cfg, ShardingPolicy(mesh=mesh, rules=rules)))(x)
np.testing.assert_allclose(np.asarray(out_local), np.asarray(out_ep),
                           atol=2e-4)
print("moe OK")

# --- 3. GAT: sharded segment ops == local ------------------------------
gcfg = gat_lib.GATConfig(name="g", n_layers=2, d_hidden=4, n_heads=2,
                         d_in=8, n_classes=3)
gp = gat_lib.init_params(key, gcfg)
N, E = 32, 64
graph = dict(
    x=jax.random.normal(key, (N, 8)),
    src=jax.random.randint(jax.random.fold_in(key, 3), (E,), 0, N),
    dst=jax.random.randint(jax.random.fold_in(key, 4), (E,), 0, N),
    edge_mask=jnp.ones((E,), bool),
    labels=jax.random.randint(jax.random.fold_in(key, 5), (N,), 0, 3),
    label_mask=jnp.ones((N,), bool))
l_local = gat_lib.loss_fn(gp, graph, gcfg, NO_SHARDING)
l_shard = jax.jit(lambda g: gat_lib.loss_fn(
    gp, g, gcfg, ShardingPolicy(mesh=mesh, rules={})))(graph)
np.testing.assert_allclose(float(l_local), float(l_shard), rtol=1e-5)
print("gat OK")

# --- 4. LM train loss: TP/SP policy == unsharded ------------------------
lcfg = tf_lib.LMConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                       n_kv_heads=2, d_head=8, d_ff=64, vocab=128,
                       dtype=jnp.float32, attn_chunk=16)
lp = tf_lib.init_params(key, lcfg)
tokens = jax.random.randint(key, (4, 32), 0, 128)
batch = {"tokens": tokens, "labels": tokens}
loss_local = tf_lib.lm_loss(lp, batch, lcfg, NO_SHARDING)
loss_shard = jax.jit(lambda p, b: tf_lib.lm_loss(
    p, b, lcfg, policy))(lp, batch)
np.testing.assert_allclose(float(loss_local), float(loss_shard), rtol=1e-4)
print("lm OK")

# --- 4b. int8 compressed psum ~ exact psum over the data axis -----------
from repro.train import compression as comp
xs = jax.random.normal(key, (8, 64)) * 2.0

def dp_sum(x):
    return jax.lax.psum(x, "data")

def dp_sum_c(x):
    return comp.compressed_psum(x, "data")

mesh1d = jax.make_mesh((8,), ("data",))
exact_sum = jax.jit(jax.shard_map(dp_sum, mesh=mesh1d, in_specs=P("data"),
                                  out_specs=P("data")))(xs)
approx_sum = jax.jit(jax.shard_map(dp_sum_c, mesh=mesh1d,
                                   in_specs=P("data"),
                                   out_specs=P("data")))(xs)
rel = float(jnp.max(jnp.abs(exact_sum - approx_sum))
            / jnp.max(jnp.abs(exact_sum)))
assert rel < 0.05, rel     # int8 quantization: ~1/127 per-rank error
print("compressed psum OK", rel)

# --- 5. elastic checkpoint: save on 8-dev mesh, restore on 2x2 ----------
from repro.train import checkpoint as ckpt
import tempfile
tree = {"w": jax.device_put(
    jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
    NamedSharding(mesh, P("data", "model")))}
d = tempfile.mkdtemp()
ckpt.save(d, 1, tree)
mesh2 = jax.make_mesh((2, 2), ("data", "model"),
                      devices=jax.devices()[:4])
sh2 = {"w": NamedSharding(mesh2, P("model", "data"))}
restored, _ = ckpt.restore(d, 1, tree, shardings=sh2)
np.testing.assert_array_equal(np.asarray(restored["w"]),
                              np.asarray(tree["w"]))
assert restored["w"].sharding == sh2["w"]
print("elastic OK")
print("ALL DISTRIBUTED OK")
"""


@pytest.mark.slow
def test_distributed_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "ALL DISTRIBUTED OK" in out.stdout
