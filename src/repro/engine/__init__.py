"""repro.engine — the unified, config-driven, mesh-aware RkMIPS engine.

This package is the only public way to run (R)kMIPS (DESIGN.md SS7):

  * ``EngineConfig`` — one frozen, hashable dataclass for every index-build
    and query knob, including the oracle-shared ``tie_eps``;
  * the method **registry** — the paper's baseline matrix (DESIGN.md SS3) as
    named presets: ``get_config("sah" | "sa-simpfer" | "h2-cone" |
    "h2-simpfer" | "simpfer" | "exact")``;
  * ``IndexArtifact`` — the first-class index artifact (engine/artifact.py,
    DESIGN.md SS10): build once, ``save``/``load`` through the SS6 elastic
    checkpoints, attach to engines on any mesh, stage streaming corpus
    deltas (``insert_items`` / ``delete_items`` / ``compact``), hot-swap
    into live servers;
  * the **staged build pipeline** (engine/build.py, DESIGN.md SS11) —
    Algorithm 4 as four pure stages with declared sharding axes;
    ``build_sah_index`` runs the row-parallel stages single-device or over
    a mesh (``EngineConfig.build_sharding``) with a bitwise-identical
    artifact either way, and reports a per-stage ``BuildTimings``;
  * ``RkMIPSEngine`` — build / attach / query / query_batch / kmips /
    oracle, with predictions always in original user-id space and an
    optional ``ShardingPolicy`` that shards the heavy scans over a mesh;
  * the **online serving subsystem** (engine/serving.py, DESIGN.md SS8) —
    ``RetrievalServer`` micro-batches single queries into fixed-size,
    statically-shaped dispatches through the sharded flat scan, with built
    state LRU-cached by (artifact fingerprint, index recipe)
    (``ServingCache`` / ``build_serving_state``); ``ReverseServer`` does
    the same for RkMIPS over the batched plan/execute pipeline (DESIGN.md
    SS9); both hot-swap artifact versions between flushes;
  * the **threaded serving runtime** (engine/runtime.py, DESIGN.md SS12) —
    ``ServingRuntime`` wraps either server in a thread pipeline: tickets
    become futures (``ServeTicket``), worker threads dispatch micro-batches
    through the servers' own flush path (bitwise-identical answers), and a
    maintenance thread compacts the delta buffer off-thread and hot-swaps
    the next ``IndexArtifact`` version in between flushes
    (``reconcile_compaction``), with ``drain``/``close`` semantics and
    per-ticket deadlines;
  * the **multi-tenant gateway** (engine/gateway.py, DESIGN.md SS15) —
    ``ServingGateway`` hosts N tenants, each a name bound to an artifact
    fingerprint plus a ``TenantPolicy`` (max k, max in-flight, per-ticket
    scan budget, default deadline), dispatching through per-tenant
    runtimes that share one ``WorkerPool`` and one compiled-trace cache
    (``share_dispatch``): identical signatures never re-trace across
    tenants, budget-truncated answers are flagged (``truncated=True`` +
    funnel snapshot), and ``gateway.stats()`` attributes counters per
    tenant;
  * ``serving_codes`` — deprecated shim over
    ``IndexArtifact.serving_codes`` (the offline sketch build behind
    ``launch/serve.py::build_candidate_index``).

``core/`` stays purely functional underneath; everything stateful (built
arrays, timings, lazy kMIPS index, pending serving tickets) lives here.
"""

from repro.engine.artifact import (IndexArtifact, corpus_fingerprint,
                                   load_artifact, reconcile_compaction)
from repro.engine.build import (BuildTimings, build_sah_index,
                                validate_build_knobs)
from repro.engine.config import (EngineConfig, PAPER_BASELINES, TIE_EPS_DEFAULT,
                                 display_name, get_config, method_names,
                                 register)
from repro.engine.engine import (KMIPSResult, PruningFunnel, QueryResult,
                                 RkMIPSEngine, serving_codes)
from repro.engine.gateway import (GatewayStats, ServingGateway, TenantPolicy)
from repro.engine.runtime import (RuntimeStats, ServeTicket, ServingRuntime,
                                  TicketExpired, WorkerPool)
from repro.engine.serving import (RetrievalServer, ReverseResult,
                                  ReverseServer, ServeResult, ServingCache,
                                  ServingState, build_serving_state,
                                  state_from_index, validate_query_rows)

__all__ = [
    "BuildTimings",
    "EngineConfig",
    "GatewayStats",
    "IndexArtifact",
    "KMIPSResult",
    "PAPER_BASELINES",
    "PruningFunnel",
    "QueryResult",
    "RetrievalServer",
    "ReverseResult",
    "ReverseServer",
    "RkMIPSEngine",
    "RuntimeStats",
    "ServeResult",
    "ServeTicket",
    "ServingCache",
    "ServingGateway",
    "ServingRuntime",
    "ServingState",
    "TIE_EPS_DEFAULT",
    "TenantPolicy",
    "TicketExpired",
    "WorkerPool",
    "build_sah_index",
    "build_serving_state",
    "corpus_fingerprint",
    "display_name",
    "get_config",
    "load_artifact",
    "method_names",
    "reconcile_compaction",
    "register",
    "serving_codes",
    "state_from_index",
    "validate_build_knobs",
]
