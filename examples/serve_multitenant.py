"""Multi-tenant gateway: N tenants, one worker pool, one trace cache.

    PYTHONPATH=src python examples/serve_multitenant.py

The walkthrough of DESIGN.md SS15:

1. build two artifact versions and stand up a ``ServingGateway``:
   ``register(name, artifact, policy=TenantPolicy(...))`` binds each
   tenant name to an artifact fingerprint plus admission limits — the
   tenants dispatch through per-tenant runtimes that SHARE one
   ``WorkerPool`` and (same config modulo ``scan_budget``) one compiled
   dispatch;
2. gateway-wide ``warmup()``: each shared signature traces once, then
   ``stats().traces_after_warmup == 0`` across ALL tenants — and stays 0
   under live traffic from every tenant;
3. a budgeted tenant (``TenantPolicy(scan_budget=...)``) gets its deep
   scans truncated *visibly*: the ticket comes back ``truncated=True``
   with a pruning-funnel snapshot, answers stay conservative (never a
   false positive vs. the unbudgeted answer), and
   ``stats().tenants[name].truncated`` attributes the count;
4. admission control: k above ``max_k`` and submits past
   ``max_in_flight`` are rejected with explicit messages, up front;
5. per-tenant lifecycle: churn + hot-swap on one tenant while the other
   keeps serving — the pool skips a locked tenant instead of queueing
   behind it, so maintenance never stalls a neighbor.
"""

import argparse

import jax
import numpy as np

from repro import IndexArtifact, get_config
from repro.data import synthetic
from repro.engine import ServingGateway, TenantPolicy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-items", type=int, default=2048)
    ap.add_argument("--m-users", type=int, default=512)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--queries", type=int, default=24)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    ki, kq, kb = jax.random.split(key, 3)
    items, users = synthetic.recommendation_data(
        ki, args.n_items, args.m_users, args.dim)
    queries = synthetic.queries_from_items(kq, items, args.queries)

    # chunk small relative to the corpus so a scan budget has chunks to
    # truncate (see tests/test_gateway.py)
    cfg = get_config("sah").replace(delta_capacity=64, serve_batch_size=4,
                                    chunk=8)
    art = IndexArtifact.build(items, users, kb, config=cfg)
    print(f"built: {art.n_base} items, fingerprint "
          f"{art.fingerprint[:16]}...")

    with ServingGateway(pool_workers=2) as gw:
        # -- 1. two tenants, one pool, one trace cache -------------------
        gw.register("prod", art, k=args.k,
                    policy=TenantPolicy(max_k=args.k, max_in_flight=256))
        gw.register("trial", art, k=args.k,
                    policy=TenantPolicy(max_k=args.k, scan_budget=1))
        print(f"tenants: {gw.tenants}; trial routes to "
              f"{gw.route('trial')[:16]}...")

        # -- 2. gateway-wide warmup --------------------------------------
        cells = gw.warmup()
        print(f"warmup: {cells} cells compiled for the shared dispatch; "
              f"traces_after_warmup={gw.stats().traces_after_warmup}")

        # -- 3. traffic from both tenants: zero retraces, budget visible -
        # a few "promo blitz" probes — noisy top-norm items pushed onto
        # the corpus's max-norm shell — defeat the O(1) pruning and force
        # deep tile scans (benchmarks/bench_adversarial.py crafts these
        # systematically); the trial tenant's budget caps them
        it = np.asarray(items)
        norms = np.linalg.norm(it, axis=-1)
        rng = np.random.default_rng(7)
        picks = it[np.argsort(norms)[-4:]]
        blitz = picks + 0.05 * rng.normal(size=picks.shape) * \
            np.linalg.norm(picks, axis=-1, keepdims=True)
        blitz *= norms.max() / np.linalg.norm(blitz, axis=-1,
                                              keepdims=True)
        mixed = np.concatenate([np.asarray(queries),
                                blitz.astype(np.float32)])
        prod = [gw.submit("prod", mixed[i])
                for i in range(mixed.shape[0])]
        trial = [gw.submit("trial", mixed[i])
                 for i in range(mixed.shape[0])]
        prod = [t.result(timeout=120) for t in prod]
        trial = [t.result(timeout=120) for t in trial]
        n_trunc = sum(r.truncated for r in trial)
        for p, t in zip(prod, trial):
            full = np.asarray(p.predictions)
            got = np.asarray(t.predictions)
            assert not np.any(got & ~full), "budget must be conservative"
        st = gw.stats()
        print(f"prod: {st.tenants['prod'].completed} tickets, "
              f"truncated={st.tenants['prod'].truncated}")
        print(f"trial: {st.tenants['trial'].completed} tickets, "
              f"truncated={st.tenants['trial'].truncated} "
              f"({n_trunc} flagged on the tickets themselves)")
        print(f"traces_after_warmup={st.traces_after_warmup} "
              f"(both tenants, live traffic)")
        if n_trunc:
            f = next(r.funnel for r in trial if r.truncated)
            print(f"  a truncated ticket's funnel: {f.format()}")

        # -- 4. admission control ----------------------------------------
        for bad in (lambda: gw.submit("trial", queries[0], k=args.k + 3),
                    lambda: gw.submit("ghost", queries[0])):
            try:
                bad()
            except (ValueError, KeyError) as e:
                print(f"rejected: {e}")

        # -- 5. per-tenant churn while the neighbor serves ---------------
        art2 = gw.insert_items("prod", np.asarray(queries[:4]) * 1.01)
        r = gw.submit("trial", queries[0]).result(timeout=120)
        print(f"prod swapped to {gw.route('prod')[:16]}... "
              f"(v{art2.delta_used} staged rows); trial answered "
              f"meanwhile (k={r.k}, swaps seen by trial: "
              f"{gw.stats().tenants['trial'].swaps})")

    print("gateway closed; all tickets resolved")


if __name__ == "__main__":
    main()
