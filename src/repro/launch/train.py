"""Production train launcher: --arch <id> [--smoke] with checkpoint-based
failure recovery and elastic restart.

On real hardware this binds the same cells the dry-run compiled (launch/
cells.py builds both); on this CPU container --smoke exercises the identical
control path (trainer, checkpointing, watchdog, recovery loop) on the
reduced configs.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 30 --ckpt-dir /tmp/ck --simulate-failure 12
"""

from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfg_base
from repro.data import graph as graph_data
from repro.data import synthetic
from repro.models import gat as gat_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tf_lib
from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt_lib
from repro.train.trainer import TrainState, make_train_step, train_loop


def _smoke_setup(arch, key):
    cfg = arch.make_smoke_config()
    if arch.family == "lm":
        params = tf_lib.init_params(key, cfg)
        data = synthetic.lm_token_batches(jax.random.fold_in(key, 1), 4, 64,
                                          cfg.vocab)
        loss = lambda p, b: tf_lib.lm_loss(p, b, cfg)
        return cfg, params, data, loss
    if arch.family == "gnn":
        rng = np.random.default_rng(0)
        g = graph_data.random_power_law_graph(rng, 256, 8, cfg.d_in,
                                              cfg.n_classes)

        def gen():
            while True:
                seeds = rng.choice(256, 16, replace=False)
                sub = graph_data.sample_subgraph(rng, g, seeds, (5, 3),
                                                 pad_nodes=256,
                                                 pad_edges=1024)
                yield {k: jnp.asarray(v) for k, v in sub.items()}

        params = gat_lib.init_params(key, cfg)
        return cfg, params, gen(), lambda p, b: gat_lib.loss_fn(p, b, cfg)
    # recsys
    if arch.arch_id in ("deepfm", "xdeepfm"):
        params = rec_lib.init_ctr_params(key, cfg)
        loss = lambda p, b: rec_lib.ctr_loss(p, b, cfg)

        def gen():
            i = 0
            while True:
                k = jax.random.fold_in(key, i)
                i += 1
                yield {"sparse": jnp.stack(
                    [jax.random.randint(jax.random.fold_in(k, j), (64,), 0,
                                        v)
                     for j, v in enumerate(cfg.embedding.vocab_sizes)], -1),
                    "label": jax.random.bernoulli(k, 0.3, (64,)).astype(
                        jnp.float32)}
        return cfg, params, gen(), loss
    if arch.arch_id == "din":
        params = rec_lib.init_din_params(key, cfg)
        vs = cfg.embedding.vocab_sizes

        def gen():
            i = 0
            while True:
                k = jax.random.fold_in(key, i)
                i += 1
                yield {
                    "hist": jax.random.randint(k, (32, cfg.seq_len), 0,
                                               vs[0]),
                    "hist_mask": jnp.ones((32, cfg.seq_len), bool),
                    "target": jax.random.randint(k, (32,), 0, vs[0]),
                    "profile": jnp.stack(
                        [jax.random.randint(jax.random.fold_in(k, j), (32,),
                                            0, v)
                         for j, v in enumerate(vs[1:])], -1),
                    "label": jax.random.bernoulli(k, 0.5, (32,)).astype(
                        jnp.float32)}
        return cfg, params, gen(), lambda p, b: rec_lib.din_loss(p, b, cfg)
    params = rec_lib.init_twotower_params(key, cfg)

    def gen():
        i = 0
        while True:
            k = jax.random.fold_in(key, i)
            i += 1
            yield {
                "user_feats": jnp.stack(
                    [jax.random.randint(jax.random.fold_in(k, j), (64,), 0,
                                        v)
                     for j, v in enumerate(cfg.user_embedding.vocab_sizes)],
                    -1),
                "item_feats": jnp.stack(
                    [jax.random.randint(jax.random.fold_in(k, 9 + j), (64,),
                                        0, v)
                     for j, v in enumerate(cfg.item_embedding.vocab_sizes)],
                    -1),
                "log_q": jnp.zeros((64,))}
    return cfg, params, gen(), lambda p, b: rec_lib.twotower_loss(p, b, cfg)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--simulate-failure", type=int, default=None,
                    help="raise a simulated worker failure at this step; "
                         "the launcher recovers from the last checkpoint")
    ap.add_argument("--max-restarts", type=int, default=2)
    args = ap.parse_args()

    arch = cfg_base.get(args.arch)
    if not args.smoke:
        print("full-scale training requires the production mesh; this "
              "container runs --smoke (same control path, reduced config)")
        return 2

    key = jax.random.PRNGKey(0)
    cfg, params, data, loss = _smoke_setup(arch, key)
    opt = opt_lib.chain(opt_lib.clip_by_global_norm(1.0),
                        opt_lib.adamw(1e-3))
    step = make_train_step(loss, opt)
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))

    fail_at = args.simulate_failure
    restarts = 0
    while True:
        if args.ckpt_dir:
            last = ckpt_lib.latest_step(args.ckpt_dir)
            if last is not None:
                state, _ = ckpt_lib.restore(args.ckpt_dir, last, state)
                print(f"[launcher] restored step {last}")
        try:
            state = train_loop(state, step, data, n_steps=args.steps,
                               ckpt_dir=args.ckpt_dir,
                               ckpt_every=args.ckpt_every,
                               fail_at_step=fail_at, log_every=10)
            break
        except RuntimeError as e:
            restarts += 1
            print(f"[launcher] worker failure: {e}; restart {restarts}")
            if restarts > args.max_restarts:
                print("[launcher] restart budget exhausted")
                return 1
            fail_at = None          # failure cleared on restart
    print(f"[launcher] training complete at step {int(state.step)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
