"""Render the EXPERIMENTS.md roofline/dry-run tables from results JSONs.

    PYTHONPATH=src python scripts/build_experiments.py > /tmp/tables.md
"""

import glob
import json
import os
import sys

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k",
               "full_graph_sm", "minibatch_lg", "ogb_products", "molecule",
               "train_batch", "serve_p99", "serve_bulk", "retrieval_cand",
               "retrieval_cand_sah"]
ARCH_ORDER = ["dbrx-132b", "olmoe-1b-7b", "qwen3-0.6b", "qwen2-1.5b",
              "mistral-nemo-12b", "gat-cora", "xdeepfm", "din", "deepfm",
              "two-tower-retrieval"]


def load(dirname):
    recs = {}
    for p in glob.glob(os.path.join(dirname, "*.json")):
        with open(p) as f:
            d = json.load(f)
        recs[(d["arch"], d["shape"], d["mesh"])] = d
    return recs


def fmt_ms(s):
    return f"{s*1e3:.2f}"


def main():
    recs = load(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")

    print("### Dry-run + roofline table (single pod, 16x16 = 256 chips)\n")
    print("| arch | shape | mem/dev GiB | compute ms | memory ms | "
          "collective ms | dominant | useful FLOPs ratio |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, "single"))
            if not r:
                continue
            rf = r["roofline"]
            ratio = r.get("useful_flops_ratio")
            print(f"| {arch} | {shape} | "
                  f"{r['memory']['per_device_total']/2**30:.2f} | "
                  f"{fmt_ms(rf['compute_s'])} | {fmt_ms(rf['memory_s'])} | "
                  f"{fmt_ms(rf['collective_s'])} | {rf['dominant']} | "
                  f"{f'{ratio:.2f}' if ratio else '--'} |")

    print("\n### Multi-pod check (2x16x16 = 512 chips): compile + fit\n")
    print("| arch | shape | mem/dev GiB | dominant | compile s |")
    print("|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, "multi"))
            if not r:
                continue
            rf = r["roofline"]
            print(f"| {arch} | {shape} | "
                  f"{r['memory']['per_device_total']/2**30:.2f} | "
                  f"{rf['dominant']} | {r['compile_s']:.1f} |")


if __name__ == "__main__":
    main()
