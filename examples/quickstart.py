"""Quickstart: build a SAH engine and answer RkMIPS queries.

    PYTHONPATH=src python examples/quickstart.py

Generates an MF-like synthetic recommendation dataset (the paper's data
regime), builds the SAH engine from its registry preset (SAT + SRP sketches
+ cone blocking + Simpfer lower bounds), answers reverse queries for a
handful of promoted items, and reports F1 against the exact oracle plus
pruning statistics. Predictions and the oracle share one EngineConfig, so
the tie tolerance can never drift between the two.
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import RkMIPSEngine, get_config
from repro.core import metrics
from repro.data import synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-items", type=int, default=8192)
    ap.add_argument("--m-users", type=int, default=16384)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--method", default="sah",
                    help="engine registry preset (sah, sa-simpfer, ...)")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    ki, kq, kb = jax.random.split(key, 3)
    items, users = synthetic.recommendation_data(
        ki, args.n_items, args.m_users, args.dim)
    queries = synthetic.queries_from_items(kq, items, args.queries)

    print(f"items={args.n_items} users={args.m_users} d={args.dim} "
          f"k={args.k} method={args.method}")
    eng = RkMIPSEngine(get_config(args.method)).build(items, users, kb)
    print(f"SAH index built in {eng.build_seconds:.2f}s "
          f"(partitions={int(eng.index.alsh.n_parts)}, "
          f"cone blocks={eng.index.n_blocks})")
    # per-stage breakdown of the staged build pipeline (DESIGN.md SS11)
    print(eng.build_timings.format())

    res = eng.query_batch(queries, args.k)
    dt = res.seconds / args.queries

    truth = eng.oracle(queries, args.k)
    f1 = metrics.f1_score(res.predictions, truth)
    print(f"\nper-query time: {dt*1e3:.1f} ms   mean F1: "
          f"{float(jnp.mean(f1)):.3f}")
    # the aggregate pruning funnel the batched plan/execute driver recovers
    # per query: blocks -> users -> scan lanes -> tiles (DESIGN.md SS9)
    print(f"pruning funnel: {res.funnel.format()}")
    for i in range(min(4, args.queries)):
        res_i = np.where(np.asarray(res.predictions[i]))[0]
        print(f"query {i}: {len(res_i)} users would see this item in their "
              f"top-{args.k}: {res_i[:8].tolist()}"
              f"{'...' if len(res_i) > 8 else ''}")


if __name__ == "__main__":
    main()
