"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds (DESIGN/EXPERIMENTS):

    compute    = HLO_FLOPs_per_device   / peak_flops      (197 TF/s bf16 v5e)
    memory     = HLO_bytes_per_device   / hbm_bw          (819 GB/s)
    collective = collective_bytes_per_device / link_bw    (~50 GB/s/link ICI)

cost_analysis() reports the per-device (post-SPMD) program, so no chip
division is needed. Collective bytes are not in cost_analysis: we parse the
compiled HLO and sum output-shape bytes of every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute instruction (output bytes
are the standard proxy for wire bytes; ring all-reduce moves ~2x, which we
fold into the reported term via the 2x factor on all-reduce).

MODEL_FLOPS (the "useful work" yardstick): 6*N*D for dense training,
6*N_active*D for MoE, 2*N*D for forward-only serving; attention FLOPs are
added explicitly since 6ND ignores them.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e-class target)
HBM_BW = 819e9               # B/s per chip
LINK_BW = 50e9               # B/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-reduce.5 = f32[16,128]{1,0} all-reduce(...)
#       ROOT %x = (bf16[4,8]{...}, f32[]) all-to-all(...)
_INSTR_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-device bytes by collective kind (output-shape proxy)."""
    out = {k: 0 for k in _COLLECTIVES}
    for m in _INSTR_RE.finditer(hlo_text):
        shape_str, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":   # started ops counted at -start
            continue
        out[kind] += _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per device
    bytes_accessed: float        # per device
    coll_bytes: dict             # per device, by kind
    peak_memory: float           # per device, bytes

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def collective_s(self) -> float:
        # ring all-reduce moves ~2x its payload (reduce-scatter+all-gather)
        b = sum(v * (2 if k == "all-reduce" else 1)
                for k, v in self.coll_bytes.items())
        return b / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops,
            "bytes_per_dev": self.bytes_accessed,
            "coll_bytes_per_dev": self.coll_bytes,
            "peak_memory_per_dev": self.peak_memory,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def cost_dict(compiled) -> dict:
    """compiled.cost_analysis() as a flat dict across jax versions (jax
    0.4.x returns a one-dict-per-program list, newer jax the dict itself)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def from_compiled(compiled) -> Roofline:
    cost = cost_dict(compiled)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    return Roofline(
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=collective_bytes(hlo),
        peak_memory=float(getattr(mem, "temp_size_in_bytes", 0)
                          + getattr(mem, "argument_size_in_bytes", 0)
                          + getattr(mem, "output_size_in_bytes", 0)
                          - getattr(mem, "alias_size_in_bytes", 0)),
    )


def model_flops(arch_id: str, shape_name: str) -> float:
    """Analytic 'useful' FLOPs per step (see EXPERIMENTS.md SSRoofline)."""
    from repro.configs import base as cfg_base
    arch = cfg_base.get(arch_id)
    shape = arch.shape(shape_name)
    dims = shape.dims

    if arch.family == "lm":
        cfg = arch.make_config()
        n_act = cfg.n_active_params
        s, b = dims["seq_len"], dims["global_batch"]
        if shape.kind == "train":
            tokens = s * b
            attn = (6 * 2 * cfg.n_layers * cfg.n_heads * cfg.head_dim
                    * s * s // 2 * b)     # fwd+bwd causal attention
            return 6.0 * n_act * tokens + attn
        if shape.kind == "prefill":
            tokens = s * b
            attn = 2 * 2 * cfg.n_layers * cfg.n_heads * cfg.head_dim \
                * s * s // 2 * b
            return 2.0 * n_act * tokens + attn
        # decode: one token/seq; attention reads the whole cache
        attn = 2 * 2 * cfg.n_layers * cfg.n_heads * cfg.head_dim * s * b
        return 2.0 * n_act * b + attn

    if arch.family == "gnn":
        cfg = arch.make_config()
        e, d = dims["n_edges"], dims["d_feat"]
        n = dims["n_nodes"]
        h, dh = cfg.n_heads, cfg.d_hidden
        # per layer: projection 2*N*d_in*H*Dh + edge ops ~ 2*E*H*(Dh+2)
        l1 = 2 * n * d * h * dh + 4 * e * h * dh
        l2 = 2 * n * h * dh * dims["n_classes"] + 4 * e * dims["n_classes"]
        fwd = l1 + l2
        return 3.0 * fwd if shape.kind == "train" else fwd

    # recsys
    cfg = arch.make_config()
    b = dims.get("batch", dims.get("n_candidates", 1))
    if arch.arch_id in ("deepfm", "xdeepfm"):
        f, d = cfg.embedding.n_fields, cfg.embedding.dim
        mlp_dims = (f * d,) + cfg.mlp_dims + (1,)
        mlp = sum(2 * a * bb for a, bb in zip(mlp_dims[:-1], mlp_dims[1:]))
        inter = 2 * f * d
        if cfg.interaction == "cin":
            sizes = (f,) + cfg.cin_layers
            inter = sum(2 * sizes[i] * f * sizes[i + 1] * d
                        for i in range(len(cfg.cin_layers)))
        fwd = b * (mlp + inter)
    elif arch.arch_id == "din":
        d = cfg.embedding.dim
        attn_dims = (4 * d,) + cfg.attn_mlp + (1,)
        attn = cfg.seq_len * sum(2 * a * bb for a, bb in
                                 zip(attn_dims[:-1], attn_dims[1:]))
        mlp_in = (2 + cfg.embedding.n_fields - 1) * d
        mlp_dims = (mlp_in,) + cfg.mlp_dims + (1,)
        mlp = sum(2 * a * bb for a, bb in zip(mlp_dims[:-1], mlp_dims[1:]))
        fwd = b * (attn + mlp)
    else:  # two-tower
        du = cfg.user_embedding.n_fields * cfg.user_embedding.dim
        di = cfg.item_embedding.n_fields * cfg.item_embedding.dim
        dims_u = (du,) + cfg.tower_dims + (cfg.out_dim,)
        dims_i = (di,) + cfg.tower_dims + (cfg.out_dim,)
        tower = sum(2 * a * bb for a, bb in zip(dims_u[:-1], dims_u[1:])) + \
            sum(2 * a * bb for a, bb in zip(dims_i[:-1], dims_i[1:]))
        if shape.kind == "retrieval":
            n = dims["n_candidates"] if isinstance(dims, dict) else 0
            n = shape.dims["n_candidates"]
            du_only = sum(2 * a * bb for a, bb in
                          zip(dims_u[:-1], dims_u[1:]))
            return du_only + 2.0 * n * cfg.out_dim
        if shape.kind == "train":
            fwd = b * tower + 2 * b * b * cfg.out_dim
            return 3.0 * fwd
        fwd = b * tower + 2 * b * cfg.out_dim
        return fwd
    return 3.0 * fwd if shape.kind == "train" else fwd
