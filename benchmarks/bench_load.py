"""Open-loop traffic bench for the serving runtime (DESIGN.md SS14).

The closed-loop cells of bench_serving.py answer "how fast is one
outstanding ticket"; this harness answers the question the north star
actually asks: under an *arrival process* — tickets landing on their own
schedule, not waiting for the previous answer — what are p50/p99 latency
and sustained QPS, and what does the first window cost when the server
has never seen a shape before?

Open-loop discipline: the arrival schedule is drawn up front (Poisson or
bursty), submission walks the wall clock, and each ticket's latency is
measured against its *intended* arrival time — if the generator falls
behind, the lateness is charged to the server, exactly like a queueing
system under load. Traffic mixes ks and query-block shapes and
interleaves corpus churn (staged inserts within the delta capacity,
compaction off), because that is the mix that defeats naive one-shape
warmup.

Every (arrivals, rate) cell runs twice on the *same* schedule:

  cold  — stock config, no bucket ladder, no warmup: the first window
          pays live XLA traces per fresh (shape, k) signature, which is
          precisely the tail cliff the warm row must not have.
  warm  — ``serve_buckets`` ladder + ``ServingRuntime(warmup=True)``:
          every (bucket, k) executable exists before the first ticket;
          the row records ``traces_after_warmup`` (CI asserts 0) and
          ``first_p99_speedup`` vs. the cold row's first window.

Rows land in the serving BENCH suite as ``load/...``:

    PYTHONPATH=src python -m benchmarks.run --scale smoke --only load
    PYTHONPATH=src python -m benchmarks.bench_load \
        --arrivals poisson --rate 24 --duration 3

The module CLI serves the CONTRIBUTING recipe and the CI smoke
(``--assert-warm`` exits nonzero unless every warm cell held
``traces_after_warmup == 0``).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks import common
from benchmarks.bench_serving import _env, _pct


def make_schedule(arrivals: str, rate: float, duration: float,
                  seed: int) -> np.ndarray:
    """Intended arrival offsets (seconds, ascending) for one cell.

    poisson: exponential gaps at ``rate`` arrivals/s — memoryless open
    traffic. bursty: the same mean rate delivered in geometric bursts
    (mean size 4) separated by exponential gaps — the schedule that
    punishes a server whose only good batch shape is the full one.
    Deterministic per (arrivals, rate, seed): the cold and warm runs of a
    cell replay the identical schedule.
    """
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    if arrivals == "poisson":
        while True:
            t += rng.exponential(1.0 / rate)
            if t >= duration:
                break
            out.append(t)
    elif arrivals == "bursty":
        mean_burst = 4
        while True:
            t += rng.exponential(mean_burst / rate)
            if t >= duration:
                break
            out.extend([t] * (1 + rng.geometric(1.0 / mean_burst)))
    else:
        raise ValueError(f"arrivals must be poisson|bursty, "
                         f"got {arrivals!r}")
    return np.asarray(out, dtype=np.float64)


def drive(rt, queries, schedule, ks, *, churn_every: int = 0,
          churn_rows=None, window: float = 1.0,
          timeout: float = 600.0) -> dict:
    """Replay ``schedule`` against a live runtime, open loop.

    Ticket i is submitted at ``schedule[i]`` (waiting if early, never
    skipping if late) with k cycling through ``ks`` and the query row
    cycling through ``queries`` — consecutive tickets mix signatures, so
    batch formation sees realistic fragmentation. With ``churn_every``
    > 0, every that-many-th arrival also stages one insert from
    ``churn_rows`` (stopping before the delta buffer would overflow).
    Latency is resolve-time minus *intended* arrival; ``first`` is the
    p99 of tickets that arrived inside the first ``window`` seconds —
    where cold-start traces live.
    """
    nq = queries.shape[0]
    tickets, churned = [], 0
    cap = (rt.artifact.delta_capacity - rt.artifact.delta_used
           if rt.artifact is not None else 0)
    base = time.perf_counter()
    for i, at in enumerate(schedule):
        lead = at - (time.perf_counter() - base)
        if lead > 0:
            time.sleep(lead)
        if churn_every and (i + 1) % churn_every == 0 and churned < cap:
            rt.insert_items(churn_rows[churned % churn_rows.shape[0]][None])
            churned += 1
        tickets.append(rt.submit(queries[i % nq], k=ks[i % len(ks)]))
    rt.drain(timeout)
    lat, first, done_at = [], [], base
    for t, at in zip(tickets, schedule):
        t.result(timeout=timeout)            # surfaces dispatch errors
        l = t.done_at - (base + at)
        lat.append(l)
        done_at = max(done_at, t.done_at)
        if at < window:
            first.append(l)
    return {
        "p50": _pct(lat, 0.5), "p99": _pct(lat, 0.99),
        "first_p99": _pct(first or lat, 0.99),
        "qps": len(tickets) / max(done_at - base, 1e-9),
        "tickets": len(tickets), "churned": churned,
        "stats": rt.stats,
    }


def _cell_rows(name, make_runtime, queries, schedule, ks, churn_rows,
               churn_every, window):
    """One (arrivals, rate) cell: cold then warm on the same schedule.
    ``make_runtime(warm)`` must return a *fresh* runtime each call —
    trace caches live on the server/engine instances, so cold means a
    new one."""
    out = {}
    for mode in ("cold", "warm"):
        rt = make_runtime(mode == "warm")
        try:
            out[mode] = drive(rt, queries, schedule, ks,
                              churn_every=churn_every,
                              churn_rows=churn_rows, window=window)
        finally:
            rt.close()
    rows = []
    for mode, m in out.items():
        s = m["stats"]
        derived = (f"p99_us={m['p99'] * 1e6:.1f};"
                   f"first_p99_us={m['first_p99'] * 1e6:.1f};"
                   f"qps={m['qps']:.1f};tickets={m['tickets']};"
                   f"churned={m['churned']};"
                   f"traces_after_warmup={s.traces_after_warmup};"
                   f"bucket_hits={s.bucket_hits};"
                   f"bucket_pad_rows={s.bucket_pad_rows};{_env()}")
        if mode == "warm":
            derived += (f";first_p99_speedup="
                        f"{out['cold']['first_p99'] / m['first_p99']:.2f}")
        rows.append(common.fmt_row(f"{name}/{mode}", m["p50"] * 1e6,
                                   derived))
    return rows


def run(n=2048, m=4096, d=64, nq=8, cap=128, *, arrivals=("poisson",
        "bursty"), rates=(16.0, 48.0), duration=3.0, window=1.0,
        reverse_rate=2.0, churn_every=10, seed=0):
    """The BENCH ``load`` suite: forward cells over every (arrivals,
    rate), plus one reverse Poisson cell — each cold vs. warm.

    Rates are arrivals/s and deliberately modest: the checked-in
    baseline runs on small CPU containers, and an open-loop bench that
    saturates the machine measures the backlog, not the server.
    """
    import jax

    from repro.dist.policy import NO_SHARDING
    from repro.engine import IndexArtifact, RkMIPSEngine, get_config

    wl = common.make_workload("nmf", n, m, d, nq, (5, 10))
    ks = (5, 10)
    # batch 4 with a (1, 2) ladder keeps the warmup grid small enough
    # for single-core CI while still exercising three distinct rungs
    base_cfg = get_config("sah").replace(k_max=50, delta_capacity=cap,
                                         serve_batch_size=4)
    warm_cfg = base_cfg.replace(serve_buckets=(1, 2))
    churn_rows = np.asarray(jax.random.permutation(
        jax.random.PRNGKey(9), wl.items)[: cap] * 1.01)

    # one artifact per config flavor (serve_buckets is execution-only,
    # but attach checks full config equality) — built once, engines and
    # servers are per-cell so every cold cell starts with no executables
    arts = {cfg: IndexArtifact.build(wl.items, wl.users,
                                     jax.random.PRNGKey(1), config=cfg)
            for cfg in (base_cfg, warm_cfg)}

    def forward_runtime(warm: bool):
        cfg = warm_cfg if warm else base_cfg
        eng = RkMIPSEngine.from_artifact(arts[cfg], policy=NO_SHARDING)
        return eng.async_server(k=ks[0], warmup=warm, warmup_ks=ks,
                                poll_interval=0.005)

    def reverse_runtime(warm: bool):
        cfg = warm_cfg if warm else base_cfg
        eng = RkMIPSEngine.from_artifact(arts[cfg], policy=NO_SHARDING)
        return eng.async_reverse_server(k=ks[0], warmup=warm,
                                        warmup_ks=ks,
                                        poll_interval=0.005)

    rows = []
    for arr in arrivals:
        for rate in rates:
            schedule = make_schedule(arr, rate, duration,
                                     seed + int(rate))
            rows.extend(_cell_rows(
                f"load/{arr}/rate={rate:g}", forward_runtime,
                wl.queries, schedule, ks, churn_rows, churn_every,
                window))
    # reverse: heavier per-ticket math, so its own (lower) rate; same
    # open-loop discipline, churn included (the engine's warmup covers
    # the delta-signature flip)
    schedule = make_schedule("poisson", reverse_rate, duration, seed + 1)
    rows.extend(_cell_rows(
        f"load/reverse/poisson/rate={reverse_rate:g}", reverse_runtime,
        wl.queries, schedule, ks, churn_rows, churn_every, window))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--arrivals", default="poisson,bursty",
                    help="comma-separated subset of poisson,bursty")
    ap.add_argument("--rate", type=float, action="append", default=None,
                    help="arrivals/s (repeatable; default 16 and 48)")
    ap.add_argument("--duration", type=float, default=3.0,
                    help="seconds of schedule per cell")
    ap.add_argument("--window", type=float, default=1.0,
                    help="first-window length (s) for first_p99")
    ap.add_argument("--reverse-rate", type=float, default=2.0,
                    help="arrivals/s of the reverse cell")
    ap.add_argument("--assert-warm", action="store_true",
                    help="fail unless every warm cell recorded "
                         "traces_after_warmup=0 (CI smoke)")
    args = ap.parse_args()
    rows = run(arrivals=tuple(args.arrivals.split(",")),
               rates=tuple(args.rate or (16.0, 48.0)),
               duration=args.duration, window=args.window,
               reverse_rate=args.reverse_rate)
    print("name,us_per_call,derived")
    for r in rows:
        print(r, flush=True)
    if args.assert_warm:
        bad = [r for r in rows if "/warm," in r
               and "traces_after_warmup=0;" not in r]
        if bad:
            raise SystemExit("warm cells traced after warmup:\n"
                             + "\n".join(bad))
        print(f"# assert-warm OK over "
              f"{sum('/warm,' in r for r in rows)} warm cells")


if __name__ == "__main__":
    main()
