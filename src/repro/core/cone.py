"""Cone blocking of user vectors (the paper's Cone-Tree, Algorithm 3).

Users are unit-normalized (Fact 2: the MIPS result -- and hence the RkMIPS
decision -- is independent of ||u||). The paper builds a recursive binary
Cone-Tree with leaf size N0 and uses its leaves as blocks, each keeping a
center N.c, max angle N.omega and per-user angles theta_u, from which the
node-level (Lemma 2) and vector-level (Lemma 3) upper bounds follow.

TPU adaptation (DESIGN.md SS2): a level-synchronous *balanced* split. At every
level each block picks pivots with the paper's rule (random v -> farthest
u_l = argmin <u,v> -> farthest-from-u_l u_r = argmin <u,u_l>) and splits at
the median of <u,u_l> - <u,u_r> instead of its sign, so every leaf has
identical size. Lemmas 2-3 hold for any grouping, so correctness is
unaffected; only pruning power differs marginally. All leaves are materialized
as contiguous runs of a permutation array -- no pointers.

Padding: m is padded to n_leaves * leaf_size by cyclically repeating real
users (unit vectors, so all cone statistics stay valid); a mask removes
duplicates from final results.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class ConeBlocks(NamedTuple):
    """Flat cone-leaf structure. n_blocks * leaf_size == m_pad.

    Attributes:
      perm:    (m_pad,) int32 -- user row ids in leaf order (leaf i owns
               perm[i*leaf : (i+1)*leaf]); ids index the *padded* user array.
      center:  (n_blocks, d) f32 -- leaf centers (unnormalized means).
      omega:   (n_blocks,) f32 -- max angle(user, center) per leaf.
      theta:   (m_pad,) f32 -- angle(user, own-leaf center), in perm order.
    """

    perm: jnp.ndarray
    center: jnp.ndarray
    omega: jnp.ndarray
    theta: jnp.ndarray

    @property
    def n_blocks(self) -> int:
        return self.center.shape[0]

    @property
    def leaf_size(self) -> int:
        return self.perm.shape[0] // self.center.shape[0]


def pad_users(users_unit: jnp.ndarray, leaf_size: int
              ) -> tuple[jnp.ndarray, jnp.ndarray, int]:
    """Pad m users to m_pad = n_leaves * leaf_size (cyclic repeat) + mask."""
    m = users_unit.shape[0]
    n_leaves = max(1, 2 ** math.ceil(math.log2(max(m / leaf_size, 1))))
    m_pad = n_leaves * leaf_size
    if m_pad < m:  # can happen when m/leaf_size rounds down to a power of 2
        n_leaves *= 2
        m_pad = n_leaves * leaf_size
    reps = -(-m_pad // m)
    padded = jnp.tile(users_unit, (reps, 1))[:m_pad]
    mask = jnp.arange(m_pad) < m
    return padded, mask, n_leaves


@functools.partial(jax.jit, static_argnames=("n_blocks", "n_levels"))
def _build(users: jnp.ndarray, key: jax.Array, *, n_blocks: int,
           n_levels: int) -> ConeBlocks:
    m_pad, d = users.shape
    order = jax.random.permutation(key, m_pad).astype(jnp.int32)

    for level in range(n_levels):
        blocks = 1 << level
        size = m_pad // blocks
        x = users[order].reshape(blocks, size, d)
        # Pivot rule of Algorithm 3 (v is random because order was shuffled).
        v = x[:, 0, :]                                          # (blocks, d)
        ip_v = jnp.einsum("bsd,bd->bs", x, v)
        u_l = jnp.take_along_axis(
            x, jnp.argmin(ip_v, axis=-1)[:, None, None], axis=1)[:, 0]
        ip_l = jnp.einsum("bsd,bd->bs", x, u_l)
        u_r = jnp.take_along_axis(
            x, jnp.argmin(ip_l, axis=-1)[:, None, None], axis=1)[:, 0]
        ip_r = jnp.einsum("bsd,bd->bs", x, u_r)
        # Balanced split at the median of cos(theta_l) - cos(theta_r):
        # descending sort => first half is the "closer to u_l" side.
        split_key = ip_l - ip_r
        sorted_idx = jnp.argsort(-split_key, axis=-1)           # (blocks, s)
        order = jnp.take_along_axis(
            order.reshape(blocks, size), sorted_idx, axis=-1).reshape(-1)

    leaf = m_pad // n_blocks
    xl = users[order].reshape(n_blocks, leaf, d)
    center = jnp.mean(xl, axis=1)                               # (nb, d)
    cnorm = jnp.linalg.norm(center, axis=-1, keepdims=True)
    cos = jnp.einsum("bld,bd->bl", xl, center) / jnp.maximum(cnorm, 1e-12)
    cos = jnp.clip(cos, -1.0, 1.0)
    theta = jnp.arccos(cos)                                     # (nb, leaf)
    omega = jnp.max(theta, axis=-1)
    return ConeBlocks(perm=order, center=center, omega=omega,
                      theta=theta.reshape(-1))


def build_cone_blocks(users_unit: jnp.ndarray, key: jax.Array,
                      leaf_size: int = 32
                      ) -> tuple[ConeBlocks, jnp.ndarray, jnp.ndarray]:
    """Build cone blocks. Returns (blocks, padded_users, user_mask).

    users_unit (m, d) must be unit vectors; padded_users is (m_pad, d) and
    perm/theta/mask index into it.
    """
    padded, mask, n_leaves = pad_users(users_unit, leaf_size)
    n_levels = int(math.log2(n_leaves))
    blocks = _build(padded, key, n_blocks=n_leaves, n_levels=n_levels)
    return blocks, padded, mask


def norm_blocks(users_unit: jnp.ndarray, leaf_size: int = 32
                ) -> tuple[ConeBlocks, jnp.ndarray, jnp.ndarray]:
    """Simpfer-style blocking: contiguous leaf_size chunks in input order.

    With unit users, Simpfer's norm intervals degenerate to a single
    interval, so its blocks are arbitrary contiguous runs (DESIGN.md SS3).
    The chunks still get honest cone statistics (center / omega / theta of
    whatever users landed together), so Lemmas 2-3 apply unchanged — the
    blocks just prune worse than Cone-Tree leaves.

    Same return contract as ``build_cone_blocks``: (blocks, padded_users,
    user_mask), with ``perm`` the identity (no reordering). One helper for
    both the legacy ``sah.build`` path and the staged build pipeline
    (``engine/build.py``), which must agree bitwise.
    """
    padded, mask, n_leaves = pad_users(users_unit, leaf_size)
    perm = jnp.arange(padded.shape[0], dtype=jnp.int32)
    xl = padded.reshape(n_leaves, leaf_size, -1)
    center = jnp.mean(xl, axis=1)
    cnorm = jnp.linalg.norm(center, axis=-1, keepdims=True)
    cos = jnp.einsum("bld,bd->bl", xl, center) / jnp.maximum(cnorm, 1e-12)
    theta_2d = jnp.arccos(jnp.clip(cos, -1.0, 1.0))
    omega = jnp.max(theta_2d, axis=-1)
    blocks = ConeBlocks(perm=perm, center=center, omega=omega,
                        theta=theta_2d.reshape(-1))
    return blocks, padded, mask


def node_upper_bound(q: jnp.ndarray, blocks: ConeBlocks) -> jnp.ndarray:
    """Lemma 2: max_{u in B} <u, q> <= ||q|| cos({phi - omega}_+), per block.

    q (d,) -> (n_blocks,). Also returns bound for use against block-level
    lower bounds.
    """
    qn = jnp.linalg.norm(q)
    cnorm = jnp.linalg.norm(blocks.center, axis=-1)
    cos_phi = (blocks.center @ q) / jnp.maximum(cnorm * qn, 1e-12)
    phi = jnp.arccos(jnp.clip(cos_phi, -1.0, 1.0))
    return qn * jnp.cos(jnp.maximum(phi - blocks.omega, 0.0)), phi


def vector_upper_bound(qn: jnp.ndarray, phi: jnp.ndarray,
                       blocks: ConeBlocks) -> jnp.ndarray:
    """Lemma 3: <u, q> <= ||q|| cos(|phi - theta_u|), per user (perm order).

    phi (n_blocks,) angles from node_upper_bound -> (m_pad,).
    """
    leaf = blocks.leaf_size
    phi_per_user = jnp.repeat(phi, leaf)
    return qn * jnp.cos(jnp.abs(phi_per_user - blocks.theta))
