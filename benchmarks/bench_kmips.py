"""Fig. 6: SA-ALSH vs H2-ALSH for standalone kMIPS (recall + query time) and
Table 2: F1 of answering RkMIPS with plain kMIPS results (they are different
problems -- the paper's motivation table).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro import RkMIPSEngine, get_config
from repro.core import exact, metrics


def run(n=16384, m=16384, d=64, nq=32, ks=(1, 5, 10, 20, 30, 40, 50)):
    wl = common.make_workload("nmf", n, m, d, nq, ks=(1, 10, 50))
    rows = []
    tv, ti = exact.kmips(wl.items, wl.queries, max(ks))

    for transform in ("sat", "qnf"):
        name = "SA-ALSH" if transform == "sat" else "H2-ALSH"
        eng = RkMIPSEngine(get_config("sah").replace(transform=transform))
        eng.build(wl.items, None, jax.random.PRNGKey(2))   # kMIPS-only
        rows.append(common.fmt_row(f"fig6/index/{name}",
                                   eng.build_seconds * 1e6, ""))
        for k in ks:
            n_cand = max(64, 4 * k)       # candidate depth scales with k
            eng.kmips(wl.queries, k, n_cand=n_cand)        # warm (compile)
            res = eng.kmips(wl.queries, k, n_cand=n_cand)
            dt = res.seconds / nq
            rec = float(jnp.mean(metrics.recall_at_k(res.ids, ti[:, :k])))
            rows.append(common.fmt_row(
                f"fig6/kmips/{name}/k={k}", dt * 1e6,
                f"recall={rec:.3f};tiles={res.tiles_visited}"))

    # Table 2: use top-k users by <u, q> as a (bad) RkMIPS answer.
    for k in (1, 10, 50):
        scores = wl.queries @ wl.users_unit.T            # (nq, m)
        _, topu = jax.lax.top_k(scores, k)
        pred = jnp.zeros(scores.shape, bool)
        pred = jax.vmap(lambda p, i: p.at[i].set(True))(pred, topu)
        f1 = float(jnp.mean(metrics.f1_score(pred, wl.truth[k])))
        rows.append(common.fmt_row(f"table2/kmips_as_rkmips/k={k}", 0.0,
                                   f"f1={f1:.3f}"))
    return rows
