"""Adversarial-query bench: scan budgets vs. SRP-pruning-defeating
traffic (DESIGN.md SS15).

The reverse pipeline's speed rests on pruning: SRP sketch codes and norm
bounds retire most (user, query) lanes before the tile scan. This harness
crafts queries that *defeat* that pruning and measures what one hostile
tenant costs the well-behaved traffic sharing its batches — and what a
``scan_budget`` buys back.

Crafting (two families, worst offenders kept by *measured* tile visits):

  * **SRP-blind probes** — unit directions drawn from the span of the
    projection matrix's smallest left-singular vectors: near-orthogonal
    to every SRP hyperplane, their code bits are signs of near-zero
    margins, so sketch distances carry almost no signal and lanes
    survive to the exact scan.
  * **Max-norm-shell probes** — noisy copies of the top-norm items,
    scaled onto the corpus's maximum-norm shell: tau lands high enough
    that norm-based O(1) decisions thin out and borderline users go to
    the scan in bulk.

Schedule: one open-loop Poisson stream (benchmarks/bench_load.py
discipline — latency charged against *intended* arrival) mixing benign
queries with an adversarial probe every ``adv_every`` tickets. The same
schedule replays against two warmed runtimes:

  unbudgeted — ``scan_budget=0``: every batch containing a probe runs
               its while-loop to the probe's depth; co-batched benign
               tickets inherit that latency.
  budgeted   — ``scan_budget`` set just above the benign pool's
               worst-case tile depth: probes get truncated (flagged
               ``truncated=True``, counted in ``RuntimeStats.truncated``
               — never silent), benign answers stay bitwise exact.

Rows land in the BENCH suite as ``adversarial/...``; the budgeted row
carries ``budget_p99_speedup=`` (unbudgeted benign p99 / budgeted benign
p99 — the number CI asserts is present) plus the truncation count.

    PYTHONPATH=src python -m benchmarks.run --scale smoke --only adversarial
    PYTHONPATH=src python -m benchmarks.bench_adversarial --duration 3
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks import common
from benchmarks.bench_load import make_schedule
from benchmarks.bench_serving import _env, _pct


def craft_adversarial(engine, n_probes: int, *, seed: int = 7,
                      pool_factor: int = 4) -> tuple[np.ndarray, dict]:
    """The ``n_probes`` worst queries for ``engine``'s index, by measured
    tile visits.

    Builds a candidate pool of SRP-blind and max-norm-shell probes
    (module docstring), runs them through ``query_batch`` against the
    live index, and keeps the candidates whose ``tiles_scanned`` is
    highest — crafted against the *actual* projection and norms, not a
    heuristic. Returns (probes (n_probes, d) f32, crafting diagnostics).
    """
    rng = np.random.default_rng(seed)
    index = engine.index
    d = int(index.users.shape[-1])
    pool_n = max(n_probes * pool_factor, n_probes + 2)

    # family 1: SRP-blind — span of the smallest left-singular vectors of
    # the (d, B) query-side projection
    proj = np.asarray(index.alsh.proj)[:-1]          # (d, B)
    u, s, _ = np.linalg.svd(proj, full_matrices=True)
    n_small = max(2, d // 8)
    basis = u[:, -n_small:]                          # (d, n_small)
    coef = rng.normal(size=(pool_n // 2, n_small))
    blind = coef @ basis.T
    blind /= np.linalg.norm(blind, axis=-1, keepdims=True) + 1e-30

    # family 2: max-norm-shell — noisy top-norm items pushed onto the
    # corpus's max-norm shell
    top = np.asarray(index.top_items)
    max_norm = float(np.asarray(index.top_norms)[0])
    picks = top[rng.integers(0, top.shape[0],
                             size=pool_n - blind.shape[0])]
    shell = picks + 0.05 * rng.normal(size=picks.shape) * \
        np.linalg.norm(picks, axis=-1, keepdims=True)
    shell *= max_norm / (np.linalg.norm(shell, axis=-1,
                                        keepdims=True) + 1e-30)
    # SRP-blind probes ride the same shell: pruning by norm must not
    # retire what pruning by code failed to
    blind *= max_norm

    pool = np.concatenate([blind, shell]).astype(np.float32)
    res = engine.query_batch(pool, min(3, engine.config.k_max))
    tiles = np.asarray(res.stats.tiles_scanned)
    worst = np.argsort(tiles)[::-1][:n_probes]
    return pool[worst], {
        "pool": pool.shape[0],
        "picked_tiles_mean": float(tiles[worst].mean()),
        "pool_tiles_mean": float(tiles.mean()),
    }


def benign_tile_budget(engine, queries, k: int, *,
                       headroom: float = 1.25) -> tuple[int, int]:
    """-> (budget, benign worst-case tiles): the smallest per-query tile
    cap that leaves the benign pool untouched, with ``headroom`` slack
    for co-residency charging (a chunk's tile visits are charged to
    every query with a lane in it, DESIGN.md SS9)."""
    res = engine.query_batch(queries, k)
    worst = int(np.asarray(res.stats.tiles_scanned).max())
    return max(1, int(worst * headroom) + 1), worst


def drive_mixed(rt, benign, probes, schedule, k: int, *,
                adv_every: int, timeout: float = 600.0) -> dict:
    """Replay ``schedule`` open-loop with a probe every ``adv_every``-th
    ticket; per-class latency (benign vs adversarial) plus per-ticket
    truncation counts out of the resolved results."""
    nb, na = benign.shape[0], probes.shape[0]
    base = time.perf_counter()
    tickets = []
    for i, at in enumerate(schedule):
        lead = at - (time.perf_counter() - base)
        if lead > 0:
            time.sleep(lead)
        adv = adv_every > 0 and (i + 1) % adv_every == 0
        q = probes[(i // adv_every) % na] if adv else benign[i % nb]
        tickets.append((rt.submit(q, k=k), at, adv))
    rt.drain(timeout)
    lat = {False: [], True: []}
    trunc = {False: 0, True: 0}
    for t, at, adv in tickets:
        r = t.result(timeout=timeout)
        lat[adv].append(t.done_at - (base + at))
        trunc[adv] += bool(getattr(r, "truncated", False))
    return {
        "benign_p50": _pct(lat[False], 0.5),
        "benign_p99": _pct(lat[False], 0.99),
        "adv_p99": _pct(lat[True] or lat[False], 0.99),
        "p99": _pct(lat[False] + lat[True], 0.99),
        "tickets": len(tickets),
        "trunc_benign": trunc[False], "trunc_adv": trunc[True],
        "stats": rt.stats,
    }


def run(n=2048, m=4096, d=64, nq=8, *, k=3, rate=24.0, duration=3.0,
        adv_every=4, n_probes=4, chunk=64, seed=0):
    """The BENCH ``adversarial`` suite: craft, then one mixed open-loop
    cell driven twice (unbudgeted vs budgeted) on the same schedule.

    ``chunk`` is deliberately small relative to the bench index so probe
    depth shows up as extra while-loop iterations rather than vanishing
    into one giant chunk (the same reason tests/test_gateway.py pins
    chunk=8).
    """
    import jax

    from repro.engine import IndexArtifact, RkMIPSEngine, get_config

    wl = common.make_workload("nmf", n, m, d, nq, (k,))
    cfg = get_config("sah").replace(k_max=max(10, k), chunk=chunk,
                                    serve_batch_size=4,
                                    serve_buckets=(1, 2))
    art = IndexArtifact.build(wl.items, wl.users, jax.random.PRNGKey(1),
                              config=cfg)

    crafter = RkMIPSEngine.from_artifact(art)
    probes, craft = craft_adversarial(crafter, n_probes, seed=seed + 7)
    budget, benign_worst = benign_tile_budget(crafter,
                                              np.asarray(wl.queries), k)

    rows = [common.fmt_row(
        "adversarial/craft", 0.0,
        f"pool={craft['pool']};probes={n_probes};"
        f"probe_tiles_mean={craft['picked_tiles_mean']:.1f};"
        f"benign_tiles_worst={benign_worst};budget={budget};{_env()}")]

    schedule = make_schedule("poisson", rate, duration, seed + 1)
    out = {}
    for mode, b in (("unbudgeted", 0), ("budgeted", budget)):
        eng = RkMIPSEngine(cfg.replace(scan_budget=b)).attach(art)
        rt = eng.async_reverse_server(k=k, warmup=True,
                                      poll_interval=0.005)
        try:
            out[mode] = drive_mixed(rt, np.asarray(wl.queries), probes,
                                    schedule, k, adv_every=adv_every)
        finally:
            rt.close()
    for mode, msr in out.items():
        s = msr["stats"]
        derived = (f"benign_p99_us={msr['benign_p99'] * 1e6:.1f};"
                   f"adv_p99_us={msr['adv_p99'] * 1e6:.1f};"
                   f"p99_us={msr['p99'] * 1e6:.1f};"
                   f"tickets={msr['tickets']};"
                   f"truncated={s.truncated};"
                   f"trunc_adv={msr['trunc_adv']};"
                   f"trunc_benign={msr['trunc_benign']};"
                   f"traces_after_warmup={s.traces_after_warmup};"
                   f"{_env()}")
        if mode == "budgeted":
            derived += (f";budget={budget};budget_p99_speedup="
                        f"{out['unbudgeted']['benign_p99'] / msr['benign_p99']:.2f}")
        rows.append(common.fmt_row(f"adversarial/mixed/{mode}",
                                   msr["benign_p50"] * 1e6, derived))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--m", type=int, default=4096)
    ap.add_argument("--nq", type=int, default=8)
    ap.add_argument("--rate", type=float, default=24.0)
    ap.add_argument("--duration", type=float, default=3.0)
    ap.add_argument("--adv-every", type=int, default=4)
    ap.add_argument("--assert-speedup", action="store_true",
                    help="exit nonzero unless the budgeted run reported "
                         "truncations and a benign-p99 speedup > 1")
    args = ap.parse_args()
    rows = run(n=args.n, m=args.m, nq=args.nq, rate=args.rate,
               duration=args.duration, adv_every=args.adv_every)
    print("name,us_per_call,derived")
    for r in rows:
        print(r)
    if args.assert_speedup:
        budgeted = [r for r in rows if "/budgeted" in r][0]
        speedup = float(budgeted.split("budget_p99_speedup=")[1])
        truncated = int(budgeted.split("truncated=")[1].split(";")[0])
        assert truncated > 0, "budgeted run truncated nothing"
        assert speedup > 1.0, f"benign p99 speedup {speedup} <= 1"
        print(f"# ok: truncated={truncated} benign_p99_speedup={speedup}")


if __name__ == "__main__":
    main()
