"""Attention: chunked (online-softmax) causal attention + distributed decode.

`chunked_attention` never materializes the (S, S) score matrix: it scans over
KV chunks carrying the running (max, denominator, accumulator) triple --
FlashAttention's recurrence expressed in pure JAX so that XLA fuses it and the
peak live intermediate is (B, H, S_q, chunk).

`decode_attention` scores one query position against a (possibly huge) KV
cache. It is written as plain max/sum reductions so that GSPMD derives the
distributed flash-decode automatically when the cache's sequence axis is
sharded: partial max -> all-reduce(max), partial sum -> all-reduce(add),
partial PV matmul -> all-reduce(add). Collective bytes per step are O(B*H*Dh),
independent of sequence length -- this is what makes `long_500k` runnable
(see DESIGN.md SS4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, Hkv, S, Dh) -> (B, Hkv*n_rep, S, Dh) for GQA."""
    if n_rep == 1:
        return x
    b, h, s, d = x.shape
    return jnp.broadcast_to(x[:, :, None], (b, h, n_rep, s, d)).reshape(
        b, h * n_rep, s, d)


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      *, chunk: int = 512, causal: bool = True) -> jnp.ndarray:
    """q (B,H,Sq,Dh), k/v (B,H,Skv,Dh) -> (B,H,Sq,Dh). Skv % chunk == 0.

    Causal masking assumes q positions are the last Sq positions of the kv
    range (standard prefill/train layout).
    """
    b, h, sq, dh = q.shape
    skv = k.shape[2]
    skv_pad = -(-skv // chunk) * chunk
    if skv_pad != skv:  # pad KV to a chunk multiple; padding is masked out
        pad = [(0, 0), (0, 0), (0, skv_pad - skv), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    scale = dh ** -0.5
    out_dtype = q.dtype
    # NOTE (SSPerf cell-2 iteration 6, refuted): computing the matmuls from
    # bf16 inputs with f32 accumulation does NOT reduce the no-fusion cost
    # model's bytes here -- the f32 score/softmax intermediates dominate and
    # the extra converts add passes. On TPU the right vehicle for that win
    # is the fused flash kernel (attn_impl="flash"), which keeps the tile in
    # VMEM end to end.
    q = (q * scale).astype(jnp.float32)
    n_chunks = skv_pad // chunk
    q_pos = jnp.arange(sq) + (skv - sq)

    k_chunks = k.reshape(b, h, n_chunks, chunk, dh).transpose(2, 0, 1, 3, 4)
    v_chunks = v.reshape(b, h, n_chunks, chunk, dh).transpose(2, 0, 1, 3, 4)

    def step(carry, xs):
        m, l, acc = carry
        (kc, vc), idx = xs
        s = jnp.einsum("bhqd,bhcd->bhqc", q, kc.astype(jnp.float32))
        kv_pos = idx * chunk + jnp.arange(chunk)
        if causal:
            mask = (q_pos[:, None] >= kv_pos[None, :]) & (kv_pos < skv)
        else:
            mask = jnp.broadcast_to(kv_pos < skv, (sq, chunk))
        s = jnp.where(mask[None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqc,bhcd->bhqd", p, vc.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, h, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    acc0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    # checkpoint: recompute the (Sq, chunk) scores in backward instead of
    # saving them per scan step (FlashAttention's memory trick; without it
    # the scan stacks n_chunks score tiles + masks -> GiBs per layer).
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step), (m0, l0, acc0),
        ((k_chunks, v_chunks), jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(out_dtype)


def naive_attention(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """Reference O(S^2)-memory attention (used by tests as the oracle)."""
    b, h, sq, dh = q.shape
    skv = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh ** -0.5
    if causal:
        q_pos = jnp.arange(sq) + (skv - sq)
        mask = q_pos[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, length: jnp.ndarray) -> jnp.ndarray:
    """One-position attention against a cache.

    q (B, H, Dh); k_cache/v_cache (B, H, Smax, Dh) (already GQA-repeated);
    length () current cache fill (positions >= length are masked).
    Written as plain reductions over the cache S axis so GSPMD derives the
    flash-decode collective schedule when S is sharded.
    """
    b, h, smax, dh = k_cache.shape
    scale = dh ** -0.5
    out_dtype = q.dtype
    s = jnp.einsum("bhd,bhsd->bhs", (q * scale).astype(jnp.float32),
                   k_cache.astype(jnp.float32))
    valid = jnp.arange(smax)[None, None, :] < length
    s = jnp.where(valid, s, _NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)          # all-reduce(max) if sharded
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)          # all-reduce(add)
    out = jnp.einsum("bhs,bhsd->bhd", p,
                     v_cache.astype(jnp.float32))   # partial + all-reduce(add)
    return (out / jnp.maximum(l, 1e-30)).astype(out_dtype)
